//! Tensor-parallel inference engine.
//!
//! Executes the per-shard HLO pieces (`attn_part`, `mlp_part`) and runs the
//! paper's quantized AllReduce on the partial outputs between pieces —
//! the real wire transformation (quantize → sum → re-quantize), applied to
//! the actual activation bytes. Residual adds happen host-side in rust,
//! exactly where a serving engine would fuse them.
//!
//! The AllReduce is the *same* [`Communicator`](crate::comm::Communicator)
//! code path the fabric collectives use: the engine owns a
//! [`LocalGroup`] — one communicator per TP shard over an in-process
//! mesh — so there is exactly one QDQ-chain implementation in the system
//! (SDP4Bit's lesson: QDQ placement is where accuracy is won or lost).
//! Which algorithm chains the QDQs is an [`AlgoPolicy`]: fixed, or `Auto`
//! against the cost model. With `tp = 1` nothing crosses a wire and the
//! boundary is a plain residual add, matching the collectives' `n == 1`
//! no-op convention.

use anyhow::{ensure, Result};

use crate::comm::{AlgoPolicy, LocalGroup};
use crate::model::{shard_param, Batch, ModelConfig, Weights};
use crate::plan::PlanPolicy;
use crate::quant::Codec;
use crate::runtime::{tokens_literal, Runtime, Tensor};
use crate::sim::MeasuredProfile;
use crate::telemetry::MetricsSnapshot;

/// Per-layer, per-shard weight literals, prepared once.
struct LayerShards {
    /// [shard] -> (ln1_g, ln1_b, wq, wk, wv, wo)
    attn: Vec<Vec<xla::Literal>>,
    /// [shard] -> (ln2_g, ln2_b, w1, w2); empty for MoE layers.
    mlp: Vec<Vec<xla::Literal>>,
}

/// Build the TP rank group for a policy, or `None` for the wire-free
/// single-shard case.
pub(crate) fn tp_group(tp: usize, policy: AlgoPolicy) -> Result<Option<LocalGroup>> {
    tp_group_grouped(tp, None, policy)
}

/// [`tp_group`] with an explicit link-tier group count (`--groups`).
pub(crate) fn tp_group_grouped(
    tp: usize,
    groups: Option<usize>,
    policy: AlgoPolicy,
) -> Result<Option<LocalGroup>> {
    Ok(if tp >= 2 { Some(LocalGroup::for_policy_grouped(tp, groups, policy)?) } else { None })
}

/// [`tp_group_grouped`] driving the plan layer (the CLI's `--plan`): the
/// group's boundary AllReduces resolve through the given [`PlanPolicy`]
/// instead of the `AlgoPolicy` shim.
pub(crate) fn tp_group_planned(
    tp: usize,
    groups: Option<usize>,
    policy: PlanPolicy,
) -> Result<Option<LocalGroup>> {
    Ok(if tp >= 2 { Some(LocalGroup::for_plan_grouped(tp, groups, policy)?) } else { None })
}

/// The TP engine: owns the runtime, the sharded weights, and the rank
/// group whose Communicators carry every boundary AllReduce.
pub struct TpEngine {
    pub rt: Runtime,
    pub cfg: ModelConfig,
    pub codec: Codec,
    policy: AlgoPolicy,
    /// Link-tier group count the rank-group topology models (`--groups`).
    groups: Option<usize>,
    group: Option<LocalGroup>,
    embed: xla::Literal,
    head: Vec<xla::Literal>, // lnf_g, lnf_b, embed (tied)
    layers: Vec<LayerShards>,
    /// If set, `last_partial` captures the raw (pre-QDQ) partial sum of
    /// this layer's MLP AllReduce — the Fig. 4 distribution.
    pub capture_layer: Option<usize>,
    pub last_partial: Vec<f32>,
}

impl TpEngine {
    /// Build from full weights, slicing TP shards per the python layout.
    pub fn new(
        rt: Runtime,
        cfg: ModelConfig,
        weights: &Weights,
        codec: Codec,
        policy: AlgoPolicy,
    ) -> Result<TpEngine> {
        TpEngine::new_grouped(rt, cfg, weights, codec, policy, None, None)
    }

    /// [`TpEngine::new`] with an explicit link-tier group count for the
    /// rank-group topology (the CLI's `--groups`) and an optional
    /// [`PlanPolicy`] (the CLI's `--plan`) — passing the plan here builds
    /// the rank group once instead of constructing an `AlgoPolicy` group
    /// that [`TpEngine::set_plan_policy`] would immediately discard.
    pub fn new_grouped(
        rt: Runtime,
        cfg: ModelConfig,
        weights: &Weights,
        codec: Codec,
        policy: AlgoPolicy,
        groups: Option<usize>,
        plan: Option<PlanPolicy>,
    ) -> Result<TpEngine> {
        ensure!(cfg.n_heads % cfg.tp == 0, "heads {} % tp {}", cfg.n_heads, cfg.tp);
        let tp = cfg.tp;
        let (group, policy) = match plan {
            Some(p) => (tp_group_planned(tp, groups, p)?, p.algo_hint()),
            None => (tp_group_grouped(tp, groups, policy)?, policy),
        };
        let embed = weights.get("embed")?.to_literal()?;
        let head = vec![
            weights.get("lnf_g")?.to_literal()?,
            weights.get("lnf_b")?.to_literal()?,
            weights.get("embed")?.to_literal()?,
        ];
        let mut layers = Vec::with_capacity(cfg.n_layers);
        for l in 0..cfg.n_layers {
            let g = |b: &str| -> Result<Tensor> { Ok(weights.get(&format!("l{l}.{b}"))?.clone()) };
            let mut attn = Vec::with_capacity(tp);
            for k in 0..tp {
                let mut args = Vec::new();
                args.push(g("ln1_g")?.to_literal()?);
                args.push(g("ln1_b")?.to_literal()?);
                for w in ["wq", "wk", "wv", "wo"] {
                    let name = format!("l{l}.{w}");
                    let sh = shard_param(&name, weights.get(&name)?, tp, k);
                    args.push(sh.to_literal()?);
                }
                attn.push(args);
            }
            let mut mlp = Vec::new();
            if !cfg.is_moe_layer(l) {
                for k in 0..tp {
                    let mut args = Vec::new();
                    args.push(g("ln2_g")?.to_literal()?);
                    args.push(g("ln2_b")?.to_literal()?);
                    for w in ["w1", "w2"] {
                        let name = format!("l{l}.{w}");
                        let sh = shard_param(&name, weights.get(&name)?, tp, k);
                        args.push(sh.to_literal()?);
                    }
                    mlp.push(args);
                }
            }
            layers.push(LayerShards { attn, mlp });
        }
        Ok(TpEngine {
            rt,
            cfg,
            codec,
            policy,
            groups,
            group,
            embed,
            head,
            layers,
            capture_layer: None,
            last_partial: Vec::new(),
        })
    }

    /// Execute one boundary: run `piece` per shard, AllReduce the partials
    /// through the Communicator group, residual-add into `h`.
    fn boundary(
        &mut self,
        piece: &str,
        h: &Tensor,
        layer: usize,
        is_mlp: bool,
    ) -> Result<Tensor> {
        let tp = self.cfg.tp;
        let h_lit = h.to_literal()?;
        let mut partials: Vec<Vec<f32>> = Vec::with_capacity(tp);
        for k in 0..tp {
            let shard_args = if is_mlp {
                &self.layers[layer].mlp[k]
            } else {
                &self.layers[layer].attn[k]
            };
            let mut args: Vec<xla::Literal> = vec![h_lit.clone()];
            args.extend(shard_args.iter().cloned());
            let out = self.rt.execute_t(piece, &args)?;
            partials.push(out.into_iter().next().unwrap().data);
        }
        if is_mlp && self.capture_layer == Some(layer) {
            // Fig. 4: the raw communicated volume (sum of shard partials).
            let mut raw = vec![0f32; partials[0].len()];
            for p in &partials {
                for (r, x) in raw.iter_mut().zip(p) {
                    *r += *x;
                }
            }
            self.last_partial = raw;
        }
        let reduced = match &mut self.group {
            Some(group) => {
                group.allreduce(&mut partials, &self.codec)?;
                std::mem::take(&mut partials[0])
            }
            None => partials.pop().unwrap(),
        };
        let mut out = h.clone();
        for (o, r) in out.data.iter_mut().zip(&reduced) {
            *o += *r;
        }
        Ok(out)
    }

    /// Full forward to the pre-head hidden state.
    pub fn forward_h(&mut self, batch: &Batch) -> Result<Tensor> {
        let cfg = self.cfg.clone();
        ensure!(
            batch.batch == cfg.eval_batch && batch.seq == cfg.seq_len,
            "batch {}x{} doesn't match lowered shapes {}x{}",
            batch.batch,
            batch.seq,
            cfg.eval_batch,
            cfg.seq_len
        );
        let toks = tokens_literal(&batch.tokens, &[batch.batch, batch.seq])?;
        let embed_name = cfg.art("embed");
        let mut h = self
            .rt
            .execute_t(&embed_name, &[toks, self.embed.clone()])?
            .into_iter()
            .next()
            .unwrap();
        let attn_piece = cfg.art(&format!("attn_part_tp{}", cfg.tp));
        let mlp_piece = cfg.art(&format!("mlp_part_tp{}", cfg.tp));
        for l in 0..cfg.n_layers {
            h = self.boundary(&attn_piece, &h, l, false)?;
            ensure!(!cfg.is_moe_layer(l), "TP engine is dense-only; use MoeEngine");
            h = self.boundary(&mlp_piece, &h, l, true)?;
        }
        Ok(h)
    }

    /// Mean next-token NLL over a batch (communication-quantized model).
    pub fn eval_nll(&mut self, batch: &Batch) -> Result<(f64, usize)> {
        let h = self.forward_h(batch)?;
        let tgts = tokens_literal(&batch.targets, &[batch.batch, batch.seq])?;
        let name = self.cfg.art("head_nll");
        let mut args = vec![h.to_literal()?];
        args.extend(self.head.iter().cloned());
        args.push(tgts);
        let out = self.rt.execute_t(&name, &args)?;
        let nll = &out[0];
        Ok((nll.data.iter().map(|&x| x as f64).sum(), nll.len()))
    }

    /// Perplexity over a set of eval batches.
    pub fn perplexity(&mut self, batches: &[Batch]) -> Result<f64> {
        let mut sum = 0.0;
        let mut count = 0usize;
        for b in batches {
            let (s, c) = self.eval_nll(b)?;
            sum += s;
            count += c;
        }
        Ok((sum / count as f64).exp())
    }

    /// Swap the codec / algorithm policy (for sweep harnesses) without
    /// resharding weights. Rebuilds the rank group only when the policy's
    /// preset topology changes; on a failed rebuild the engine keeps its
    /// previous (consistent) policy + group. Clears any plan policy set
    /// via [`TpEngine::set_plan_policy`] (the two surfaces are exclusive).
    pub fn set_codec(&mut self, codec: Codec, policy: AlgoPolicy) -> Result<()> {
        self.codec = codec;
        if policy != self.policy || self.plan_policy().is_some() {
            self.group = tp_group_grouped(self.cfg.tp, self.groups, policy)?;
            self.policy = policy;
        }
        Ok(())
    }

    /// Route the boundary AllReduces through the plan layer (the CLI's
    /// `--plan`): rebuilds the rank group for `plan`, keeping the current
    /// codec as the base budget `Auto` compiles against. On a failed
    /// rebuild (e.g. an inadmissible fixed plan for the preset topology)
    /// the engine keeps its previous consistent group.
    pub fn set_plan_policy(&mut self, plan: PlanPolicy) -> Result<()> {
        if self.plan_policy() == Some(&plan) {
            return Ok(()); // already driving exactly this policy
        }
        self.group = tp_group_planned(self.cfg.tp, self.groups, plan)?;
        self.policy = plan.algo_hint();
        Ok(())
    }

    /// The active algorithm policy.
    pub fn policy(&self) -> AlgoPolicy {
        self.policy
    }

    /// The active plan policy, when the engine drives the plan layer.
    pub fn plan_policy(&self) -> Option<&PlanPolicy> {
        self.group.as_ref().and_then(LocalGroup::plan_policy)
    }

    /// Turn the flight recorder on for every TP shard
    /// ([`LocalGroup::enable_recording`]). No-op with `tp = 1`: nothing
    /// crosses a wire, so there is nothing to record. Note that
    /// [`TpEngine::set_codec`] / [`TpEngine::set_plan_policy`] may rebuild
    /// the rank group, dropping the recorders — re-enable after swapping.
    pub fn enable_recording(&mut self, capacity: usize) {
        if let Some(group) = &mut self.group {
            group.enable_recording(capacity);
        }
    }

    /// Per-shard flight-recorder trace JSON, rank order (empty while
    /// recording is off or with `tp = 1`). Schema: DESIGN.md §11.
    pub fn trace_jsons(&self) -> Vec<String> {
        self.group.as_ref().map(LocalGroup::trace_jsons).unwrap_or_default()
    }

    /// Distill a [`MeasuredProfile`] from the shards' recorded traces and
    /// install it on every shard, so subsequent `--plan auto` resolution
    /// prices the measured rates
    /// ([`LocalGroup::recalibrate_from_recorders`]).
    pub fn recalibrate_from_recorders(&mut self) -> Option<MeasuredProfile> {
        self.group.as_mut()?.recalibrate_from_recorders()
    }

    /// Group-wide metrics snapshot over the boundary AllReduces
    /// ([`LocalGroup::metrics_snapshot`]); `None` with `tp = 1`.
    pub fn metrics_snapshot(&self) -> Option<MetricsSnapshot> {
        self.group.as_ref().map(LocalGroup::metrics_snapshot)
    }

    /// The head-piece weight literals (lnf_g, lnf_b, tied embedding) — used
    /// by harnesses that run alternative head artifacts (e.g. `head_acc`).
    pub fn head_literals(&self) -> Vec<xla::Literal> {
        self.head.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::Algo;
    use crate::quant::CodecBuffers;
    use crate::util::stats::sqnr_db;

    /// The QDQ chain `coordinator::tp::allreduce_partials` applied before
    /// the Communicator unification — kept verbatim as the golden
    /// reference: QDQ every partial, sum, QDQ the result (two-step), with
    /// a per-half bridge QDQ for the hierarchical chain.
    fn prerefactor_chain(partials: &[Vec<f32>], codec: &Codec, hier: bool) -> Vec<f32> {
        let mut bufs = CodecBuffers::default();
        let n = partials.len();
        let len = partials[0].len();
        if !hier {
            let mut acc = vec![0f32; len];
            for p in partials {
                let mut p = p.clone();
                codec.qdq(&mut p, &mut bufs);
                for (a, x) in acc.iter_mut().zip(&p) {
                    *a += *x;
                }
            }
            codec.qdq(&mut acc, &mut bufs);
            acc
        } else {
            let half = n.div_ceil(2);
            let mut total = vec![0f32; len];
            for group in [0..half, half..n] {
                if group.is_empty() {
                    continue;
                }
                let mut acc = vec![0f32; len];
                for p in &partials[group] {
                    let mut p = p.clone();
                    codec.qdq(&mut p, &mut bufs);
                    for (a, x) in acc.iter_mut().zip(&p) {
                        *a += *x;
                    }
                }
                codec.qdq(&mut acc, &mut bufs); // bridge hop
                for (t, x) in total.iter_mut().zip(&acc) {
                    *t += *x;
                }
            }
            codec.qdq(&mut total, &mut bufs); // all-gather hop
            total
        }
    }

    fn partials(n: usize, len: usize) -> Vec<Vec<f32>> {
        let mut rng = crate::util::Prng::new(5);
        (0..n)
            .map(|_| {
                let mut v = vec![0f32; len];
                rng.fill_normal(&mut v, 0.0, 1.0);
                v
            })
            .collect()
    }

    #[test]
    fn unified_twostep_matches_prerefactor_golden() {
        // Acceptance pin: the Communicator-driven TP boundary reproduces
        // the pre-refactor QDQ-chain numerics. len = tp·gs·k keeps the
        // quantization groups chunk-aligned, so the only difference from
        // the old whole-vector chain is that the real collective keeps the
        // receiving rank's own chunk at full precision pre-sum — a
        // quantization-noise-sized term. Agreement must sit far above the
        // codec's own error floor.
        let parts = partials(4, 256);
        let exact: Vec<f32> = (0..256).map(|i| parts.iter().map(|p| p[i]).sum::<f32>()).collect();
        let codec = Codec::parse("int8@32").unwrap();

        let mut group = tp_group(4, AlgoPolicy::Fixed(Algo::TwoStep)).unwrap().unwrap();
        let mut mine = parts.clone();
        group.allreduce(&mut mine, &codec).unwrap();

        let s = sqnr_db(&exact, &mine[0]);
        assert!(s > 25.0, "accuracy vs exact sum: SQNR {s} dB");
        let golden = prerefactor_chain(&parts, &codec, false);
        let agree = sqnr_db(&golden, &mine[0]);
        assert!(agree > 20.0, "vs pre-refactor golden chain: {agree} dB");
    }

    #[test]
    fn unified_hier_matches_prerefactor_golden() {
        let parts = partials(4, 256);
        let exact: Vec<f32> = (0..256).map(|i| parts.iter().map(|p| p[i]).sum::<f32>()).collect();
        let codec = Codec::parse("int8@32").unwrap();

        let mut group = tp_group(4, AlgoPolicy::Fixed(Algo::Hier)).unwrap().unwrap();
        let mut mine = parts.clone();
        group.allreduce(&mut mine, &codec).unwrap();

        let s = sqnr_db(&exact, &mine[0]);
        assert!(s > 20.0, "hier accuracy vs exact sum: SQNR {s} dB");
        let golden = prerefactor_chain(&parts, &codec, true);
        let agree = sqnr_db(&golden, &mine[0]);
        assert!(agree > 18.0, "vs pre-refactor hier golden chain: {agree} dB");
        // Hier applies one extra QDQ: slightly worse than two-step, close.
        let mut two = tp_group(4, AlgoPolicy::Fixed(Algo::TwoStep)).unwrap().unwrap();
        let mut mine2 = parts.clone();
        two.allreduce(&mut mine2, &codec).unwrap();
        let s2 = sqnr_db(&exact, &mine2[0]);
        assert!(s > s2 - 6.0 && s <= s2 + 1.5, "hier {s} vs two-step {s2}");
    }

    #[test]
    fn bf16_partials_golden_exact_value() {
        // Hard golden pin (identical pre- and post-refactor): BF16 partials
        // 1.5 and −0.25 reduce to exactly 1.25 on every rank — every
        // intermediate is bf16-representable.
        let mut parts = vec![vec![1.5f32; 64], vec![-0.25f32; 64]];
        let mut group = tp_group(2, AlgoPolicy::Fixed(Algo::TwoStep)).unwrap().unwrap();
        group.allreduce(&mut parts, &Codec::Bf16).unwrap();
        for rank in &parts {
            for &x in rank {
                assert_eq!(x.to_bits(), 1.25f32.to_bits(), "{x}");
            }
        }
    }

    #[test]
    fn single_shard_group_is_none() {
        assert!(tp_group(1, AlgoPolicy::Auto).unwrap().is_none());
        assert!(tp_group(2, AlgoPolicy::Auto).unwrap().is_some());
        assert!(tp_group_planned(1, None, PlanPolicy::auto()).unwrap().is_none());
    }

    #[test]
    fn planned_tp_group_runs_mixed_boundary_allreduce() {
        use crate::plan::{CommPlan, StageCodecs};
        let c4 = Codec::parse("int4@32").unwrap();
        let plan = CommPlan {
            stage_codecs: StageCodecs::with_cross(c4, Codec::parse("int2-sr@32!").unwrap()),
            ..CommPlan::uniform(Algo::Hier, c4)
        };
        let mut group =
            tp_group_planned(4, None, PlanPolicy::Fixed(plan)).unwrap().unwrap();
        assert_eq!(group.plan_policy(), Some(&PlanPolicy::Fixed(plan)));
        let parts = partials(4, 256);
        let exact: Vec<f32> = (0..256).map(|i| parts.iter().map(|p| p[i]).sum::<f32>()).collect();
        let mut mine = parts.clone();
        group.allreduce(&mut mine, &c4).unwrap();
        for r in &mine {
            assert_eq!(r, &mine[0], "TP shards must agree bitwise under a mixed plan");
        }
        let s = sqnr_db(&exact, &mine[0]);
        assert!(s > 5.0, "mixed TP boundary SQNR {s} dB");
    }
}
