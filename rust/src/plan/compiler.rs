//! The plan search: admissible candidates, priced by the calibrated sim.
//!
//! [`compile`] is a pure function of `(Topology, element count, base
//! codec)` — no clocks, no randomness, no per-rank state — so every rank
//! of a job compiles the *same* plan without coordination (the same
//! property [`AlgoPolicy::Auto`](crate::comm::AlgoPolicy) already relied
//! on, extended to the full plan space).
//!
//! ## Search space
//!
//! - **Algorithm**: the admissible subset of
//!   `{ring (BF16 only), twostep, hier, hierpp}` — identical candidate
//!   rules to `AlgoPolicy::Auto` (a quantized ring is never a candidate;
//!   the hierarchical family needs `G >= 2` + an inter-group link).
//! - **Cross-stage codec**: the [`cross_codec_ladder`] of the base codec —
//!   the base itself plus codecs strictly no less aggressive
//!   (asymptotically fewer wire bytes per value), e.g.
//!   `int8 → {int8, int4@32, int2-sr@32!}`. Mixed-stage candidates are
//!   admitted **only when the link tiers are genuinely asymmetric**
//!   (`inter_bw × TIER_ASYMMETRY <= intra_bw`): requantizing the cross
//!   ring more aggressively costs accuracy the timing model cannot see,
//!   so it must be justified by a slow tier, not by a rounding-error win
//!   on a balanced box. The paper's L40 bridge (≈ PCIe speed) stays
//!   uniform; a 25 GB/s inter-node link under NVLink nodes does not.
//!   Intra stages always keep the base codec (SDP4Bit's split: aggression
//!   goes where the slow link is).
//! - **Micro-chunk count** (`hierpp`): [`CHUNK_CANDIDATES`], priced
//!   through the pipeline DAG scheduler — more chunks overlap better but
//!   pay per-chunk launch latency and metadata overhead.
//! - **Send window**: the cost model's DAG needs exactly one chunk of RS
//!   traffic in flight ahead of the reducer to realize the Fig. 8
//!   overlap; any larger window only raises the peak in-flight memory
//!   bound. The search therefore fixes the smallest overlap-preserving
//!   window ([`SEND_WINDOW`](crate::comm::SEND_WINDOW)) unless the caller
//!   pins one.
//!
//! Ties break toward the earlier candidate; candidates are generated
//! simplest-first (one-shot before hierarchical, uniform before mixed,
//! fewer chunks before more), so equal-cost plans resolve to the simpler
//! schedule.

use crate::comm::{Algo, SEND_WINDOW};
use crate::quant::{Codec, ScaleMode};
use crate::sim;
use crate::topo::Topology;

use super::{CommPlan, PlanPins, StageCodecs};

/// How much slower the inter-group link must be than the intra fabric
/// before mixed-stage (aggressive-cross) candidates enter the search.
pub const TIER_ASYMMETRY: f64 = 2.0;

/// Micro-chunk counts the `hierpp` candidates sweep (the sim's Fig. 8
/// curve peaks inside this range for every calibrated device).
pub const CHUNK_CANDIDATES: &[usize] = &[2, 4, 8, 16];

/// Codecs admissible on the cross-group stage for a given base codec: the
/// base itself first, then progressively more aggressive family members
/// (never *less* aggressive — the base codec is the caller's accuracy
/// budget, and the fast intra stages already run it).
///
/// BF16 is a lossless budget: the ladder is just `[bf16]` — `Auto` never
/// introduces quantization loss the caller didn't opt into. The
/// Hadamard/LogFMT baselines stay uniform too (they exist as paper
/// comparison points, not production codecs).
pub fn cross_codec_ladder(base: &Codec) -> Vec<Codec> {
    let mut ladder = vec![*base];
    match *base {
        Codec::Bf16 | Codec::Hadamard { .. } | Codec::LogFmt { .. } => {}
        Codec::Rtn { bits, scale_mode, .. } => {
            if bits > 4 {
                ladder.push(Codec::Rtn { bits: 4, group_size: 32, scale_mode });
            }
            if bits > 2 {
                // The paper's most aggressive production point: INT2 with
                // spike reserving and integer (Eq. 1) metadata.
                ladder.push(Codec::Spike { bits: 2, group_size: 32, scale_mode: ScaleMode::IntLog });
            }
        }
        Codec::Spike { bits, group_size, scale_mode } => {
            if bits > 2 {
                ladder.push(Codec::Spike { bits: 2, group_size, scale_mode });
            }
        }
    }
    debug_assert!(
        ladder.windows(2).all(|w| {
            w[1].asymptotic_wire_ratio() <= w[0].asymptotic_wire_ratio() + 1e-12
        }),
        "ladder must be monotonically more aggressive: {ladder:?}"
    );
    ladder
}

/// Are this topology's link tiers asymmetric enough to justify a more
/// aggressive cross-stage codec? (See the module docs for why this gates
/// the mixed-stage candidates instead of letting pure timing decide.)
pub fn tiers_asymmetric(topo: &Topology) -> bool {
    match topo.inter_bw() {
        Some(inter) => inter * TIER_ASYMMETRY <= topo.spec.intra_bw(),
        None => false,
    }
}

/// Compile the fastest admissible plan for `elems` f32 values under the
/// `base` codec budget on `topo`. Deterministic; see the module docs for
/// the search space.
pub fn compile(topo: &Topology, elems: usize, base: &Codec) -> CommPlan {
    compile_pinned(topo, elems, base, PlanPins::default())
}

/// [`compile`] with pinned knobs: a pinned chunk count replaces the
/// [`CHUNK_CANDIDATES`] sweep, a pinned window replaces the default for
/// every pipelined candidate. Pins constrain the pipelined candidates —
/// they do not force the algorithm choice (a pinned chunk count on a
/// payload that prices one-shot fastest still compiles to the one-shot).
pub fn compile_pinned(topo: &Topology, elems: usize, base: &Codec, pins: PlanPins) -> CommPlan {
    let m_bytes = 2.0 * elems as f64; // sim convention: BF16 payload bytes
    let mut best: Option<(CommPlan, f64)> = None;
    let mut consider = |plan: CommPlan| {
        let t = sim::plan_time(topo, &plan, m_bytes).total();
        if best.map(|(_, bt)| t < bt).unwrap_or(true) {
            best = Some((plan, t));
        }
    };

    // One-shot candidates (always uniform): the BF16 ring baseline and
    // the two-step. A quantized ring is never a candidate — its error
    // compounds over N−1 hops (same rule as AlgoPolicy::Auto).
    if matches!(base, Codec::Bf16) {
        consider(CommPlan::uniform(Algo::Ring, *base));
    }
    consider(CommPlan::uniform(Algo::TwoStep, *base));

    if Algo::Hier.admissible(topo).is_ok() {
        let ladder =
            if tiers_asymmetric(topo) { cross_codec_ladder(base) } else { vec![*base] };
        let window = pins.window.unwrap_or(SEND_WINDOW);
        let pinned_chunks = pins.chunks.map(|c| vec![c]);
        let chunk_candidates: &[usize] = match &pinned_chunks {
            Some(one) => one,
            None => CHUNK_CANDIDATES,
        };
        for cross in ladder {
            let stage_codecs = StageCodecs::with_cross(*base, cross);
            consider(CommPlan {
                algo: Algo::Hier,
                stage_codecs,
                chunks: 1,
                send_window: 1,
                codec_threads: 0,
            });
            for &chunks in chunk_candidates {
                consider(CommPlan {
                    algo: Algo::HierPipelined,
                    stage_codecs,
                    chunks,
                    send_window: window,
                    codec_threads: 0,
                });
            }
        }
    }

    // lint: allow(panic, "the two-step candidate is unconditionally pushed, so best is Some")
    best.expect("the two-step candidate is always admissible").0
}

/// Compile over the *surviving* membership after `lost` ranks died: the
/// degraded-mode re-plan. Builds the
/// [`survivor_topology`](crate::session::survivor_topology) (grouped when
/// losses were group-uniform, flat otherwise) and compiles the fastest
/// admissible plan for it — so the plan running over a
/// [`DegradedMesh`](crate::session::DegradedMesh) never references a dead
/// rank and never reuses full-membership admissibility (e.g. a hier plan
/// degrades to one-shot when the survivors flatten). Returns the plan
/// together with the survivor topology, whose changed fingerprint keys
/// the plan cache away from the pre-loss entries. Deterministic like
/// [`compile`]: every survivor re-plans identically without coordination,
/// given the same (sorted) loss set.
pub fn compile_degraded(
    topo: &Topology,
    lost: &[usize],
    elems: usize,
    base: &Codec,
) -> Result<(CommPlan, Topology), crate::comm::CommError> {
    let survivors = crate::session::survivor_topology(topo, lost)?;
    let plan = compile(&survivors, elems, base);
    Ok((plan, survivors))
}

/// [`compile_pinned`] against live measurements: every sane term of
/// `profile` (effective intra/inter bandwidth, QDQ pass rate — typically
/// distilled from flight-recorder traces by
/// [`crate::telemetry::distill_profile`]) overrides the static
/// calibration's priced rate via [`Topology::recalibrated`], so a
/// mis-calibrated static topology gets corrected by what the ranks
/// actually measured. An empty profile makes this exactly
/// [`compile_pinned`]. Determinism is preserved: the profile is an input
/// like any other, so identical (topology, profile) pairs compile the
/// same plan on every rank.
pub fn compile_profiled(
    topo: &Topology,
    elems: usize,
    base: &Codec,
    pins: PlanPins,
    profile: &sim::MeasuredProfile,
) -> CommPlan {
    compile_pinned(&profile.apply(topo), elems, base, pins)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topo::presets;

    fn c(s: &str) -> Codec {
        Codec::parse(s).unwrap()
    }

    const MB: usize = 1024 * 1024;

    #[test]
    fn ladder_is_aggressive_only_and_starts_at_base() {
        for base in ["bf16", "int8", "int5", "int4@32", "int3-sr@32", "int2-sr@32!", "int2@32"] {
            let base = c(base);
            let ladder = cross_codec_ladder(&base);
            assert_eq!(ladder[0], base, "ladder starts at the budget");
            for step in &ladder {
                assert!(
                    step.asymptotic_wire_ratio() <= base.asymptotic_wire_ratio() + 1e-12,
                    "{} less aggressive than base {}",
                    step.spec(),
                    base.spec()
                );
                step.validate().unwrap();
            }
        }
        assert_eq!(cross_codec_ladder(&Codec::Bf16).len(), 1, "bf16 budget stays lossless");
        assert_eq!(cross_codec_ladder(&c("int8")).len(), 3);
        assert_eq!(cross_codec_ladder(&c("int2-sr@32!")).len(), 1, "already at the floor");
    }

    #[test]
    fn asymmetry_gate_matches_the_link_tiers() {
        // L40's bridge (18.9 GB/s) ~= its PCIe fabric (19): balanced, no
        // mixed-stage candidates. The dual-NVLink cluster's 25 GB/s
        // inter-node link under 212 GB/s NVLink: strongly asymmetric.
        assert!(!tiers_asymmetric(&Topology::new(presets::l40(), 8)));
        assert!(!tiers_asymmetric(&presets::four_group_pcie(8).unwrap()));
        assert!(tiers_asymmetric(&presets::dual_nvlink_node(8).unwrap()));
        assert!(!tiers_asymmetric(&Topology::new(presets::h800(), 8)), "flat: no inter link");
    }

    #[test]
    fn duo_large_payload_compiles_mixed_and_aggressive() {
        // Acceptance pin: on the dual-NVLink cluster, payloads >= 1 MB
        // compile to a hierarchical plan whose cross codec is at least as
        // aggressive as the intra stages — and strictly more aggressive
        // for an int4 base (the slow link dominates; see ISSUE).
        let duo = presets::dual_nvlink_node(8).unwrap();
        let base = c("int4@32");
        for elems in [512 * 1024, 4 * MB, 32 * MB] {
            let plan = compile(&duo, elems, &base);
            assert!(
                matches!(plan.algo, Algo::Hier | Algo::HierPipelined),
                "{elems}: {plan}"
            );
            assert!(plan.cross_no_less_aggressive(), "{elems}: {plan}");
            assert!(
                plan.stage_codecs.cross.asymptotic_wire_ratio()
                    < plan.stage_codecs.intra_rs.asymptotic_wire_ratio(),
                "{elems}: cross must be strictly more aggressive, got {plan}"
            );
            assert_eq!(plan.stage_codecs.intra_rs, base, "intra stages keep the budget");
        }
        // Tiny payloads stay on the latency-optimal one-shot, uniform.
        let small = compile(&duo, 256, &base);
        assert_eq!(small.algo, Algo::TwoStep, "{small}");
        assert!(small.stage_codecs.is_uniform());
    }

    #[test]
    fn balanced_l40_compiles_uniform() {
        // Acceptance pin (the other half of the crossover): the balanced
        // L40 box never mixes stages — aggression without a slow tier is
        // pure accuracy loss.
        let l40 = Topology::new(presets::l40(), 8);
        for elems in [8 * 1024, MB, 32 * MB] {
            let plan = compile(&l40, elems, &c("int4@32"));
            assert!(plan.stage_codecs.is_uniform(), "{elems}: {plan}");
        }
    }

    #[test]
    fn compile_is_deterministic() {
        let duo = presets::dual_nvlink_node(8).unwrap();
        let l40 = Topology::new(presets::l40(), 8);
        for topo in [&duo, &l40] {
            for spec in ["bf16", "int8", "int4@32", "int2-sr@32!"] {
                for elems in [1usize, 4096, MB, 32 * MB] {
                    let first = compile(topo, elems, &c(spec));
                    for _ in 0..10 {
                        assert_eq!(compile(topo, elems, &c(spec)), first, "{spec}@{elems}");
                    }
                    assert_eq!(compile(&topo.clone(), elems, &c(spec)), first, "fresh topo");
                }
            }
        }
    }

    #[test]
    fn pins_constrain_the_pipelined_candidates() {
        // Positive control: on the L40 box at 64 MB, int5 two-step loses
        // to hier and hier loses to the 8-chunk pipelined variant (both
        // pinned by existing sim tests: `l40_low_bits_win_and_hier_beats_
        // twostep`, `l40_pipelining_beats_serial_hier`), so pinning
        // chunks = 8 must compile to hierpp carrying exactly the pinned
        // knobs — window never enters the pricing, so any pinned window
        // rides along unchanged.
        let l40 = Topology::new(presets::l40(), 8);
        let base = c("int5");
        let pins = PlanPins { chunks: Some(8), window: Some(4) };
        let plan = compile_pinned(&l40, 32 * MB, &base, pins);
        assert_eq!(plan.algo, Algo::HierPipelined, "{plan}");
        assert_eq!((plan.chunks, plan.send_window), (8, 4), "{plan}");
        // Pins constrain, they do not force: whatever wins a pinned
        // search either is not pipelined or carries the pins verbatim.
        let duo = presets::dual_nvlink_node(8).unwrap();
        for elems in [256usize, MB, 32 * MB] {
            let pins = PlanPins { chunks: Some(5), window: Some(3) };
            let plan = compile_pinned(&duo, elems, &c("int4@32"), pins);
            plan.validate(&duo).unwrap();
            if plan.algo == Algo::HierPipelined {
                assert_eq!((plan.chunks, plan.send_window), (5, 3), "{plan}");
            }
            assert_eq!(compile_pinned(&duo, elems, &c("int4@32"), pins), plan, "deterministic");
        }
    }

    #[test]
    fn measured_profile_recalibrates_a_miscalibrated_topology() {
        // Acceptance pin for profile-guided recalibration. The static
        // topology deliberately lies: it claims a 200 GB/s inter-group
        // link under H800 NVLink groups, so the tiers look balanced and
        // the static search never admits a mixed-stage candidate. The
        // measured truth is a 10 GB/s link. The profiled compile must
        // (a) pick a different plan, and (b) price strictly faster than
        // the static pick under the *true* rates.
        let static_topo = Topology::try_custom(presets::h800(), 8, 2, Some(200e9)).unwrap();
        let truth = sim::MeasuredProfile {
            inter_bw: Some(10e9),
            ..sim::MeasuredProfile::default()
        };
        let base = c("int4@32");
        let elems = 32 * MB;
        let static_plan = compile(&static_topo, elems, &base);
        assert!(
            static_plan.stage_codecs.is_uniform(),
            "balanced-looking tiers must stay uniform: {static_plan}"
        );
        let profiled_plan =
            compile_profiled(&static_topo, elems, &base, PlanPins::default(), &truth);
        assert_ne!(static_plan, profiled_plan, "live measurements must change the pick");
        let true_topo = truth.apply(&static_topo);
        assert!(tiers_asymmetric(&true_topo), "the measured link is genuinely slow");
        let m = 2.0 * elems as f64;
        let t_static = sim::plan_time(&true_topo, &static_plan, m).total();
        let t_profiled = sim::plan_time(&true_topo, &profiled_plan, m).total();
        assert!(
            t_profiled < t_static,
            "profiled plan {profiled_plan} ({t_profiled}s) must beat the static pick \
             {static_plan} ({t_static}s) under the true rates"
        );
        // An empty profile changes nothing.
        let empty = sim::MeasuredProfile::default();
        assert_eq!(
            compile_profiled(&static_topo, elems, &base, PlanPins::default(), &empty),
            static_plan
        );
    }

    #[test]
    fn compiled_plans_always_validate() {
        for topo in [
            Topology::new(presets::h800(), 8),
            Topology::new(presets::l40(), 8),
            presets::four_group_pcie(8).unwrap(),
            presets::dual_nvlink_node(8).unwrap(),
        ] {
            for spec in ["bf16", "int8", "int4@32", "int2-sr@32!", "int4-had@32"] {
                for elems in [0usize, 1, 4096, MB] {
                    let plan = compile(&topo, elems, &c(spec));
                    plan.validate(&topo).unwrap_or_else(|e| {
                        panic!("{spec}@{elems} on {}: {plan}: {e}", topo.spec.name)
                    });
                }
            }
        }
    }

    #[test]
    fn bf16_budget_never_quantized() {
        let duo = presets::dual_nvlink_node(8).unwrap();
        let plan = compile(&duo, 32 * MB, &Codec::Bf16);
        assert!(plan.stage_codecs.is_uniform());
        assert_eq!(plan.stage_codecs.cross, Codec::Bf16);
    }
}
