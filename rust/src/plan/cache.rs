//! The plan cache: compile once, replay allocation-free.
//!
//! [`compiler::compile`](super::compiler::compile) prices dozens of
//! candidates (the pipelined ones build event-scheduler DAGs), which is
//! far too much work to repeat on every AllReduce of a training step. The
//! cache keys a compiled [`CommPlan`] by [`PlanKey`] — the topology
//! *fingerprint* (a hash of every field the pricing reads), the payload
//! element count, the base codec, and any pinned knobs — so the hot path
//! compiles each distinct shape once and then replays it from a
//! move-to-front LRU list with zero allocation (entries are `Copy`; the
//! backing `Vec` never grows past its construction capacity).
//!
//! Hit/miss counters are public: tests pin "zero recompiles after
//! warmup" by asserting the miss counter stays flat across repeated
//! same-shape calls.

use super::{CommPlan, PlanPins};
use crate::quant::Codec;
use crate::topo::Topology;

/// What a compiled plan is keyed by. Two calls with equal keys are
/// guaranteed the same plan (the compiler is a pure function of exactly
/// these inputs).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct PlanKey {
    /// [`Topology::fingerprint`] — covers every topology/spec field the
    /// cost model reads, so equal fingerprints price identically.
    pub topo_fingerprint: u64,
    /// Payload length in f32 elements.
    pub elems: usize,
    /// The base codec (the caller's dtype budget the search refines).
    pub base: Codec,
    /// Pinned knobs constraining the search (`--chunks` / `--window`).
    pub pins: PlanPins,
}

impl PlanKey {
    pub fn new(topo: &Topology, elems: usize, base: &Codec, pins: PlanPins) -> PlanKey {
        PlanKey { topo_fingerprint: topo.fingerprint(), elems, base: *base, pins }
    }
}

/// Point-in-time cache counters (monotone over a cache's lifetime).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PlanCacheStats {
    /// Lookups answered from the cache (no compile).
    pub hits: u64,
    /// Lookups that missed (each one cost a compile).
    pub misses: u64,
    /// Entries evicted to make room (capacity pressure indicator).
    pub evictions: u64,
}

/// A fixed-capacity, move-to-front LRU of compiled plans.
#[derive(Debug)]
pub struct PlanCache {
    /// Most-recently-used first. Linear scan: capacities are tiny (a
    /// handful of live (topology, size, codec) shapes per job) and the
    /// entries are `Copy`, so a scan beats a heap-allocating map.
    entries: Vec<(PlanKey, CommPlan)>,
    cap: usize,
    hits: u64,
    misses: u64,
    evictions: u64,
}

/// Default capacity for a communicator-owned cache: comfortably above the
/// distinct (payload size × codec) shapes a training/serving loop cycles
/// through, small enough that the linear scan is free.
pub const DEFAULT_CAPACITY: usize = 16;

impl Default for PlanCache {
    fn default() -> Self {
        PlanCache::new(DEFAULT_CAPACITY)
    }
}

impl PlanCache {
    /// A cache holding at most `cap >= 1` plans.
    pub fn new(cap: usize) -> PlanCache {
        let cap = cap.max(1);
        PlanCache { entries: Vec::with_capacity(cap), cap, hits: 0, misses: 0, evictions: 0 }
    }

    /// Look `key` up, counting a hit (and refreshing its LRU position) or
    /// a miss.
    pub fn get(&mut self, key: &PlanKey) -> Option<CommPlan> {
        match self.entries.iter().position(|(k, _)| k == key) {
            Some(i) => {
                self.hits += 1;
                // Move-to-front without allocating.
                self.entries[..=i].rotate_right(1);
                Some(self.entries[0].1)
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    /// Insert a freshly compiled plan at the front, evicting the
    /// least-recently-used entry if at capacity. Inserting an existing key
    /// refreshes its plan and position.
    pub fn insert(&mut self, key: PlanKey, plan: CommPlan) {
        if let Some(i) = self.entries.iter().position(|(k, _)| *k == key) {
            self.entries[..=i].rotate_right(1);
            self.entries[0] = (key, plan);
            return;
        }
        if self.entries.len() == self.cap {
            self.entries.pop();
            self.evictions += 1;
        }
        // Insert at the back then rotate to the front: no reallocation
        // once the Vec has reached capacity.
        self.entries.push((key, plan));
        self.entries.rotate_right(1);
    }

    /// The compiled plan for `key`, compiling via `compile` on a miss.
    pub fn get_or_insert_with(
        &mut self,
        key: PlanKey,
        compile: impl FnOnce() -> CommPlan,
    ) -> CommPlan {
        match self.get(&key) {
            Some(p) => p,
            None => {
                let p = compile();
                self.insert(key, p);
                p
            }
        }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn stats(&self) -> PlanCacheStats {
        PlanCacheStats { hits: self.hits, misses: self.misses, evictions: self.evictions }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::Algo;
    use crate::topo::presets;

    fn key(elems: usize) -> PlanKey {
        let topo = Topology::new(presets::l40(), 8);
        PlanKey::new(&topo, elems, &Codec::parse("int4@32").unwrap(), PlanPins::default())
    }

    fn plan(algo: Algo) -> CommPlan {
        CommPlan::uniform(algo, Codec::parse("int4@32").unwrap())
    }

    #[test]
    fn hit_after_insert_miss_before() {
        let mut c = PlanCache::new(4);
        assert_eq!(c.get(&key(100)), None);
        c.insert(key(100), plan(Algo::Hier));
        assert_eq!(c.get(&key(100)), Some(plan(Algo::Hier)));
        assert_eq!(c.get(&key(200)), None);
        assert_eq!(c.stats(), PlanCacheStats { hits: 1, misses: 2, evictions: 0 });
    }

    #[test]
    fn lru_evicts_least_recently_used() {
        let mut c = PlanCache::new(2);
        c.insert(key(1), plan(Algo::Hier));
        c.insert(key(2), plan(Algo::TwoStep));
        // Touch key(1) so key(2) is now the LRU victim.
        assert!(c.get(&key(1)).is_some());
        c.insert(key(3), plan(Algo::Ring));
        assert_eq!(c.len(), 2);
        assert!(c.get(&key(1)).is_some(), "recently used survives");
        assert!(c.get(&key(2)).is_none(), "LRU victim evicted");
        assert_eq!(c.stats().evictions, 1);
    }

    #[test]
    fn reinserting_a_key_refreshes_not_duplicates() {
        let mut c = PlanCache::new(4);
        c.insert(key(1), plan(Algo::Hier));
        c.insert(key(1), plan(Algo::TwoStep));
        assert_eq!(c.len(), 1);
        assert_eq!(c.get(&key(1)), Some(plan(Algo::TwoStep)));
    }

    #[test]
    fn capacity_never_grows_after_warmup() {
        let mut c = PlanCache::new(3);
        for i in 0..10 {
            c.get_or_insert_with(key(i), || plan(Algo::Hier));
        }
        let cap = c.entries.capacity();
        for i in 0..10 {
            c.get_or_insert_with(key(i), || plan(Algo::TwoStep));
        }
        assert_eq!(c.entries.capacity(), cap, "hot path must not reallocate");
        assert!(c.len() <= 3);
    }

    #[test]
    fn distinct_pins_are_distinct_keys() {
        let topo = Topology::new(presets::l40(), 8);
        let base = Codec::parse("int4@32").unwrap();
        let free = PlanKey::new(&topo, 100, &base, PlanPins::default());
        let pinned =
            PlanKey::new(&topo, 100, &base, PlanPins { chunks: Some(4), window: None });
        assert_ne!(free, pinned);
        let mut c = PlanCache::new(4);
        c.insert(free, plan(Algo::Hier));
        assert!(c.get(&pinned).is_none(), "pinned search must not reuse the free plan");
    }

    #[test]
    fn fingerprint_distinguishes_topologies() {
        let base = Codec::parse("int8").unwrap();
        let a = PlanKey::new(&Topology::new(presets::l40(), 8), 64, &base, PlanPins::default());
        let b = PlanKey::new(&Topology::new(presets::h800(), 8), 64, &base, PlanPins::default());
        let c4 = PlanKey::new(&presets::four_group_pcie(8).unwrap(), 64, &base, PlanPins::default());
        assert_ne!(a, b);
        assert_ne!(a, c4);
        // Identical topologies fingerprint identically (cache hits across
        // clones — the whole point of the key).
        let a2 = PlanKey::new(&Topology::new(presets::l40(), 8), 64, &base, PlanPins::default());
        assert_eq!(a, a2);
    }
}
