//! The communication plan compiler.
//!
//! FlashCommunication V2's wins come from software–hardware co-design:
//! chunk granularity and quantization aggressiveness are tuned *per link
//! tier* — the slow cross-group ring can afford a more aggressive codec
//! than the fast intra-group stages (SDP4Bit mixes quantization across
//! communication phases the same way), and the micro-chunk count that
//! hides the inter-group hop is a cost-model question, not a constant.
//!
//! This module turns that tuning into a typed artifact:
//!
//! - [`CommPlan`] — everything the execution layer needs for one
//!   AllReduce: the algorithm, a [`Codec`] per hierarchical stage
//!   ([`StageCodecs`]: intra reduce-scatter / cross-group column ring /
//!   intra all-gather), the micro-chunk count, the pipelined send window,
//!   and the codec worker-thread budget.
//! - [`compiler`] — searches the admissible plan space for a
//!   `(Topology, element count, base codec)` triple and prices every
//!   candidate with the calibrated simulator
//!   ([`crate::sim::plan_time`]), deterministically: same inputs, same
//!   plan, on every rank, with no coordination.
//! - [`cache`] — an LRU [`PlanCache`](cache::PlanCache) keyed by
//!   `(topology fingerprint, element count, base codec, pins)` so the hot
//!   path compiles a plan once and then replays it allocation-free
//!   (hit/miss counters are public — tests pin "zero recompiles after
//!   warmup").
//!
//! The transport backend is a pricing dimension the compiler does *not*
//! model yet: plans are priced against link bandwidths alone, while the
//! UDP datagram backend (DESIGN.md §13) adds per-datagram sub-header
//! overhead (16 B / 1200 B chunk), forward tail redundancy, and a paced
//! send rate that adapts to measured delivery — all visible in
//! `TransportStats` and `BENCH_transport.json` (UDP-vs-TCP rows on the
//! tier-asymmetric 25 GB/s shape) but priced as if the wire were free of
//! them. Folding a per-backend overhead term into [`crate::sim::plan_time`]
//! is the designed extension point once those recorded baselines show the
//! gap matters for plan choice.
//!
//! [`PlanPolicy`] is how callers choose: `Fixed(CommPlan)` runs exactly
//! one plan, `Auto` compiles per (topology, size, codec). The older
//! [`crate::comm::AlgoPolicy`] survives as a thin shim — its
//! `Fixed`/`Auto` arms now build *uniform* plans (one codec for every
//! stage, default knobs) and run them through the same plan execution
//! path, so there is exactly one collective driver in the system.
//!
//! ## Plan spec grammar (CLI `--plan`)
//!
//! ```text
//! auto
//! <algo>[:intra=<c>][:cross=<c>][:ag=<c>][:chunks=<K>][:window=<W>][:threads=<T>]
//! ```
//!
//! `<algo>` is an [`Algo`] token (`ring|twostep|hier|hierpp`); codecs use
//! the [`Codec::parse`] grammar. Unset stage codecs default to the call's
//! base codec (`--codec`); `cross` and `ag` default to `intra`.
//! `chunks`/`window` default to the pipelined constants
//! ([`crate::comm::DEFAULT_CHUNKS`] / [`crate::comm::SEND_WINDOW`]) for
//! `hierpp` and to 1 otherwise (and are *rejected* on algorithms that
//! would ignore them); `threads` defaults to 0 = inherit the
//! communicator's
//! [`codec_threads`](crate::comm::Communicator::set_codec_threads).

pub mod cache;
pub mod compiler;

use std::fmt;

use anyhow::{bail, ensure, Context, Result};

use crate::comm::{Algo, CommError, DEFAULT_CHUNKS, SEND_WINDOW};
use crate::quant::Codec;
use crate::topo::Topology;

pub use cache::{PlanCache, PlanCacheStats, PlanKey};
pub use compiler::{
    compile, compile_degraded, compile_pinned, compile_profiled, cross_codec_ladder,
    TIER_ASYMMETRY,
};

/// The codec each stage of the hierarchical family runs. The stage
/// boundaries are the *existing* QDQ boundaries (each stage re-encodes its
/// freshly reduced f32 accumulator), so mixing codecs across stages keeps
/// the 3-pass QDQ count — requantization costs nothing extra structurally.
/// One-stage algorithms (ring, two-step) must be uniform.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct StageCodecs {
    /// Stage 1: intra-group reduce-scatter over the fast fabric.
    pub intra_rs: Codec,
    /// Stage 2: the cross-group column ring over the (possibly much
    /// slower) inter-group link — the stage that can afford aggression.
    pub cross: Codec,
    /// Stage 3: intra-group all-gather over the fast fabric.
    pub intra_ag: Codec,
}

impl StageCodecs {
    /// One codec for every stage (what every pre-plan collective ran).
    pub fn uniform(codec: Codec) -> StageCodecs {
        StageCodecs { intra_rs: codec, cross: codec, intra_ag: codec }
    }

    /// Base codec on the fast intra stages, `cross` on the slow ring.
    pub fn with_cross(intra: Codec, cross: Codec) -> StageCodecs {
        StageCodecs { intra_rs: intra, cross, intra_ag: intra }
    }

    pub fn is_uniform(&self) -> bool {
        self.intra_rs == self.cross && self.cross == self.intra_ag
    }

    /// Structural validation of every stage codec ([`Codec::validate`]).
    pub fn validate(&self) -> Result<()> {
        for (stage, c) in [
            ("intra-rs", &self.intra_rs),
            ("cross", &self.cross),
            ("intra-ag", &self.intra_ag),
        ] {
            c.validate().with_context(|| format!("{stage} stage codec {}", c.spec()))?;
        }
        Ok(())
    }
}

impl fmt::Display for StageCodecs {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.is_uniform() {
            write!(f, "{}", self.intra_rs.spec())
        } else {
            write!(
                f,
                "{}/{}/{}",
                self.intra_rs.spec(),
                self.cross.spec(),
                self.intra_ag.spec()
            )
        }
    }
}

/// A compiled communication plan: one AllReduce, fully specified.
///
/// Construction: [`CommPlan::uniform`] (the [`crate::comm::AlgoPolicy`]
/// shim shape), [`CommPlan::parse`] (the CLI `--plan` grammar), or
/// [`compiler::compile`] (the cost-model search). [`CommPlan::validate`]
/// is the admission check every execution entry point runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct CommPlan {
    /// The AllReduce algorithm family.
    pub algo: Algo,
    /// Per-stage wire codecs (uniform for one-stage algorithms).
    pub stage_codecs: StageCodecs,
    /// Micro-chunk count ([`Algo::HierPipelined`] only; 1 otherwise).
    pub chunks: usize,
    /// In-flight intra-RS window in micro-chunks (pipelined only).
    pub send_window: usize,
    /// Codec worker threads; 0 = inherit the communicator's setting.
    pub codec_threads: usize,
}

impl CommPlan {
    /// The plan the [`crate::comm::AlgoPolicy`] shim runs: one codec
    /// everywhere, the pre-plan constants for the knobs.
    pub fn uniform(algo: Algo, codec: Codec) -> CommPlan {
        let (chunks, send_window) = match algo {
            Algo::HierPipelined => (DEFAULT_CHUNKS, SEND_WINDOW),
            _ => (1, 1),
        };
        CommPlan {
            algo,
            stage_codecs: StageCodecs::uniform(codec),
            chunks,
            send_window,
            codec_threads: 0,
        }
    }

    /// Parse the `--plan` spec grammar (module docs) against the call's
    /// base codec (unset stage codecs default to it).
    pub fn parse(spec: &str, base: &Codec) -> Result<CommPlan> {
        let mut parts = spec.split(':');
        let algo: Algo = parts
            .next()
            .unwrap_or_default()
            .parse()
            .with_context(|| format!("plan spec '{spec}'"))?;
        let mut intra: Option<Codec> = None;
        let mut cross: Option<Codec> = None;
        let mut ag: Option<Codec> = None;
        let mut chunks: Option<usize> = None;
        let mut window: Option<usize> = None;
        let mut threads: Option<usize> = None;
        for part in parts {
            let Some((key, value)) = part.split_once('=') else {
                bail!("plan spec '{spec}': expected key=value, got '{part}'");
            };
            match key {
                "intra" => intra = Some(Codec::parse(value)?),
                "cross" => cross = Some(Codec::parse(value)?),
                "ag" => ag = Some(Codec::parse(value)?),
                "chunks" => {
                    chunks = Some(value.parse().with_context(|| format!("chunks={value}"))?)
                }
                "window" => {
                    window = Some(value.parse().with_context(|| format!("window={value}"))?)
                }
                "threads" => {
                    threads = Some(value.parse().with_context(|| format!("threads={value}"))?)
                }
                other => bail!(
                    "plan spec '{spec}': unknown key '{other}' \
                     (expected intra|cross|ag|chunks|window|threads)"
                ),
            }
        }
        let intra = intra.unwrap_or(*base);
        let defaults = CommPlan::uniform(algo, intra);
        let plan = CommPlan {
            algo,
            stage_codecs: StageCodecs {
                intra_rs: intra,
                cross: cross.unwrap_or(intra),
                intra_ag: ag.unwrap_or(intra),
            },
            chunks: chunks.unwrap_or(defaults.chunks),
            send_window: window.unwrap_or(defaults.send_window),
            codec_threads: threads.unwrap_or(0),
        };
        plan.validate_shape().with_context(|| format!("plan spec '{spec}'"))?;
        Ok(plan)
    }

    /// Topology-independent structural checks: stage codecs valid, knobs
    /// sane (`chunks >= 1`, `window >= 1`), one-stage algorithms uniform,
    /// and chunking knobs only on the algorithm that reads them — a knob
    /// the execution layer would silently ignore is an error, not a no-op.
    pub fn validate_shape(&self) -> Result<()> {
        self.stage_codecs.validate()?;
        ensure!(self.chunks >= 1, "a plan needs chunks >= 1 (chunks == 0 chunks nothing)");
        ensure!(self.send_window >= 1, "a plan needs window >= 1 (a zero window never sends)");
        if matches!(self.algo, Algo::Ring | Algo::TwoStep) {
            ensure!(
                self.stage_codecs.is_uniform(),
                "{} has no cross-group stage: per-stage codecs {} would silently not apply \
                 (use hier/hierpp for mixed-stage plans)",
                self.algo,
                self.stage_codecs
            );
        }
        if !matches!(self.algo, Algo::HierPipelined) {
            ensure!(
                self.chunks == 1 && self.send_window == 1,
                "chunks/window are pipelined knobs: {} runs unchunked, so chunks={} \
                 window={} would be silently ignored (use hierpp)",
                self.algo,
                self.chunks,
                self.send_window
            );
        }
        Ok(())
    }

    /// Full admission check: structural shape plus [`Algo::admissible`]
    /// on `topo`. Every plan execution entry point runs this.
    pub fn validate(&self, topo: &Topology) -> Result<(), CommError> {
        self.validate_shape().map_err(|e| CommError::Shape { detail: format!("{e:#}") })?;
        self.algo.admissible(topo)
    }

    /// The single codec of a uniform plan — what the one-stage
    /// collectives (reduce-scatter / all-gather / broadcast / all2all)
    /// run. Mixed-stage plans are an error there: those collectives have
    /// no cross-group stage, so a distinct `cross` codec would silently
    /// not apply.
    pub fn uniform_codec(&self) -> Result<Codec> {
        ensure!(
            self.stage_codecs.is_uniform(),
            "a one-stage collective takes a uniform plan; per-stage codecs {} only apply \
             to the hierarchical AllReduce",
            self.stage_codecs
        );
        Ok(self.stage_codecs.intra_rs)
    }

    /// Stable 64-bit fingerprint of the plan: FNV-1a over the canonical
    /// spec string ([`fmt::Display`]), so it is identical across ranks,
    /// OS processes, and platforms. Worker ranks exchange fingerprints to
    /// assert every rank resolved the same plan; recorded telemetry
    /// events carry it so traces are attributable to the plan that ran.
    /// (Deliberately not `DefaultHasher` — that is randomly seeded per
    /// process.)
    pub fn fingerprint(&self) -> u64 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for b in self.to_string().bytes() {
            h ^= u64::from(b);
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
        h
    }

    /// Is the cross-stage codec at least as aggressive (no more wire
    /// bytes per value, asymptotically) as the intra stages? True for
    /// every compiler-produced plan; fixed plans may do anything valid.
    pub fn cross_no_less_aggressive(&self) -> bool {
        self.stage_codecs.cross.asymptotic_wire_ratio()
            <= self.stage_codecs.intra_rs.asymptotic_wire_ratio() + 1e-12
    }
}

impl fmt::Display for CommPlan {
    /// Canonical, re-parseable spec (given the same base codec).
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}:intra={}:cross={}",
            self.algo.token(),
            self.stage_codecs.intra_rs.spec(),
            self.stage_codecs.cross.spec()
        )?;
        if self.stage_codecs.intra_ag != self.stage_codecs.intra_rs {
            write!(f, ":ag={}", self.stage_codecs.intra_ag.spec())?;
        }
        if matches!(self.algo, Algo::HierPipelined) {
            write!(f, ":chunks={}:window={}", self.chunks, self.send_window)?;
        }
        if self.codec_threads != 0 {
            write!(f, ":threads={}", self.codec_threads)?;
        }
        Ok(())
    }
}

/// Pinned plan knobs (the CLI's `--chunks` / `--window`): constrain the
/// `Auto` search instead of being overwritten by it.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub struct PlanPins {
    /// Pin the micro-chunk count (`Some(0)` is rejected at parse time).
    pub chunks: Option<usize>,
    /// Pin the pipelined send window (`Some(0)` rejected at parse time).
    pub window: Option<usize>,
}

impl PlanPins {
    pub fn is_empty(&self) -> bool {
        self.chunks.is_none() && self.window.is_none()
    }

    /// Validate pinned values (`--chunks 0` / `--window 0` are errors,
    /// never silently coerced).
    pub fn validate(&self) -> Result<()> {
        if let Some(c) = self.chunks {
            ensure!(c >= 1, "--chunks must be >= 1 (got {c})");
        }
        if let Some(w) = self.window {
            ensure!(w >= 1, "--window must be >= 1 (got {w})");
        }
        Ok(())
    }

    /// Apply the pins to an already-built plan (the `Fixed` path — the
    /// `Auto` path feeds them into the search via
    /// [`compiler::compile_pinned`] instead).
    pub fn apply(&self, mut plan: CommPlan) -> CommPlan {
        if let Some(c) = self.chunks {
            plan.chunks = c;
        }
        if let Some(w) = self.window {
            plan.send_window = w;
        }
        plan
    }
}

/// How a communicator picks the plan for an AllReduce call. Subsumes
/// [`crate::comm::AlgoPolicy`] (now a thin shim building uniform plans):
/// `Fixed` runs exactly one [`CommPlan`], `Auto` compiles per (topology,
/// payload size, base codec) through the plan cache.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PlanPolicy {
    /// Always run this plan (error if the topology cannot host it).
    Fixed(CommPlan),
    /// Compile per call: search the admissible plan space (algorithm ×
    /// cross-stage codec ladder × chunk count), priced by the calibrated
    /// cost model, honoring any pinned knobs. Deterministic — a pure
    /// function of (topology, element count, base codec, pins) — and
    /// cached, so every rank of a job lands on the same plan without
    /// coordination and the hot path compiles once.
    Auto(PlanPins),
}

impl PlanPolicy {
    /// `Auto` with no pinned knobs (what `--plan auto` parses to).
    pub fn auto() -> PlanPolicy {
        PlanPolicy::Auto(PlanPins::default())
    }

    /// The [`AlgoPolicy`](crate::comm::AlgoPolicy)-shaped hint used to
    /// pick a rank-group preset topology for this policy (see
    /// [`preset_topo_grouped`](crate::comm::preset_topo_grouped)).
    pub fn algo_hint(&self) -> crate::comm::AlgoPolicy {
        match self {
            PlanPolicy::Fixed(p) => crate::comm::AlgoPolicy::Fixed(p.algo),
            PlanPolicy::Auto(_) => crate::comm::AlgoPolicy::Auto,
        }
    }
}

impl fmt::Display for PlanPolicy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            PlanPolicy::Fixed(p) => write!(f, "{p}"),
            PlanPolicy::Auto(_) => f.write_str("auto"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topo::presets;

    fn c(s: &str) -> Codec {
        Codec::parse(s).unwrap()
    }

    #[test]
    fn uniform_plan_matches_preplan_constants() {
        let p = CommPlan::uniform(Algo::HierPipelined, c("int4@32"));
        assert_eq!((p.chunks, p.send_window), (DEFAULT_CHUNKS, SEND_WINDOW));
        assert!(p.stage_codecs.is_uniform());
        assert_eq!(p.codec_threads, 0, "uniform plans inherit the communicator's threads");
        let p = CommPlan::uniform(Algo::TwoStep, c("int8"));
        assert_eq!((p.chunks, p.send_window), (1, 1));
    }

    #[test]
    fn spec_grammar_roundtrips() {
        let base = c("int4@32");
        let p = CommPlan::parse("hier:cross=int2-sr@32!", &base).unwrap();
        assert_eq!(p.algo, Algo::Hier);
        assert_eq!(p.stage_codecs.intra_rs, base);
        assert_eq!(p.stage_codecs.cross, c("int2-sr@32!"));
        assert_eq!(p.stage_codecs.intra_ag, base);
        assert!(!p.stage_codecs.is_uniform());

        let p = CommPlan::parse("hierpp:intra=int8:cross=int4@32:chunks=4:window=3", &base)
            .unwrap();
        assert_eq!(p.stage_codecs.intra_rs, c("int8"));
        assert_eq!(p.stage_codecs.intra_ag, c("int8"), "ag defaults to intra");
        assert_eq!((p.chunks, p.send_window), (4, 3));
        // Display is canonical and re-parses to the same plan.
        let again = CommPlan::parse(&p.to_string(), &base).unwrap();
        assert_eq!(again, p);

        // An explicit ag codec parses, executes as its own stage, and
        // survives the Display roundtrip.
        let p = CommPlan::parse("hier:cross=int2-sr@32!:ag=int8", &base).unwrap();
        assert_eq!(p.stage_codecs.intra_rs, base);
        assert_eq!(p.stage_codecs.intra_ag, c("int8"));
        assert_eq!(CommPlan::parse(&p.to_string(), &base).unwrap(), p);

        // Bare algorithm = the uniform shim plan.
        assert_eq!(CommPlan::parse("twostep", &base).unwrap(), CommPlan::uniform(Algo::TwoStep, base));
    }

    #[test]
    fn hostile_specs_rejected() {
        let base = c("int8");
        assert!(CommPlan::parse("warp", &base).is_err(), "unknown algo");
        assert!(CommPlan::parse("hier:speed=11", &base).is_err(), "unknown key");
        assert!(CommPlan::parse("hierpp:chunks=0", &base).is_err(), "zero chunks");
        assert!(CommPlan::parse("hierpp:window=0", &base).is_err(), "zero window");
        assert!(CommPlan::parse("hier:cross", &base).is_err(), "missing value");
        assert!(CommPlan::parse("hier:cross=int2-sr@300", &base).is_err(), "invalid codec");
        // One-stage algorithms cannot carry a different cross codec.
        let e = CommPlan::parse("twostep:cross=int4@32", &base).unwrap_err();
        assert!(format!("{e:#}").contains("no cross-group stage"), "{e:#}");
        // Chunking knobs on an algorithm that ignores them are errors,
        // never silent no-ops.
        for spec in ["hier:chunks=8", "hier:window=4", "twostep:chunks=2", "ring:window=3"] {
            let e = CommPlan::parse(spec, &c("bf16")).unwrap_err();
            assert!(format!("{e:#}").contains("pipelined knobs"), "{spec}: {e:#}");
        }
    }

    #[test]
    fn validate_checks_topology_admissibility() {
        let flat = Topology::new(presets::h800(), 8);
        let numa = Topology::new(presets::l40(), 8);
        let plan = CommPlan::uniform(Algo::Hier, c("int8"));
        assert!(plan.validate(&numa).is_ok());
        let e = plan.validate(&flat).unwrap_err();
        assert!(matches!(e, CommError::Topology { algo: Algo::Hier, .. }), "{e}");
        // A structurally bad plan fails before topology checks.
        let bad = CommPlan { chunks: 0, ..CommPlan::uniform(Algo::Hier, c("int8")) };
        assert!(matches!(bad.validate(&numa).unwrap_err(), CommError::Shape { .. }));
    }

    #[test]
    fn pins_validate_and_apply() {
        assert!(PlanPins { chunks: Some(0), window: None }.validate().is_err());
        assert!(PlanPins { chunks: None, window: Some(0) }.validate().is_err());
        let pins = PlanPins { chunks: Some(5), window: Some(4) };
        pins.validate().unwrap();
        let p = pins.apply(CommPlan::uniform(Algo::HierPipelined, c("int8")));
        assert_eq!((p.chunks, p.send_window), (5, 4));
        assert!(PlanPins::default().is_empty());
    }

    #[test]
    fn fingerprints_are_stable_and_distinguish_plans() {
        let base = CommPlan::uniform(Algo::Hier, c("int4@32"));
        // Pure function of the plan: repeated calls and value copies agree.
        assert_eq!(base.fingerprint(), base.fingerprint());
        assert_eq!(base.fingerprint(), { base }.fingerprint());
        // Every field that changes the canonical spec changes the print.
        let pp = CommPlan::uniform(Algo::HierPipelined, c("int4@32"));
        let variants = [
            CommPlan::uniform(Algo::TwoStep, c("int4@32")),
            CommPlan::uniform(Algo::Hier, c("int8")),
            CommPlan {
                stage_codecs: StageCodecs::with_cross(c("int4@32"), c("int2-sr@32!")),
                ..base
            },
            CommPlan { codec_threads: 4, ..base },
            CommPlan { chunks: 8, send_window: 2, ..pp },
            CommPlan { chunks: 4, send_window: 2, ..pp },
        ];
        let mut fps: Vec<u64> = variants.iter().map(CommPlan::fingerprint).collect();
        fps.push(base.fingerprint());
        let uniq: std::collections::HashSet<u64> = fps.iter().copied().collect();
        assert_eq!(uniq.len(), fps.len(), "fingerprint collision: {fps:?}");
    }

    #[test]
    fn aggressiveness_ordering() {
        let mixed = CommPlan {
            stage_codecs: StageCodecs::with_cross(c("int4@32"), c("int2-sr@32!")),
            ..CommPlan::uniform(Algo::Hier, c("int4@32"))
        };
        assert!(mixed.cross_no_less_aggressive());
        let inverted = CommPlan {
            stage_codecs: StageCodecs::with_cross(c("int2-sr@32!"), c("int8")),
            ..CommPlan::uniform(Algo::Hier, c("int2-sr@32!"))
        };
        assert!(!inverted.cross_no_less_aggressive());
        assert!(CommPlan::uniform(Algo::Hier, c("int8")).cross_no_less_aggressive());
    }

    #[test]
    fn display_names_stage_codecs() {
        let mixed = StageCodecs::with_cross(c("int4@32"), c("int2-sr@32!"));
        assert_eq!(mixed.to_string(), "int4@32/int2-sr@32!/int4@32");
        assert_eq!(StageCodecs::uniform(c("bf16")).to_string(), "bf16");
    }
}
