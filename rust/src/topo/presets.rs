//! Table 6 device presets with calibration.
//!
//! Two kinds of numbers live here:
//!
//! 1. **Paper constants** (Table 6): SM counts, nominal bandwidth,
//!    CUDA-core BF16 TFLOP/s, comm-kernel SM budget (48, except H20 = 78).
//! 2. **Calibration constants**: effective link bandwidths and QDQ pass
//!    rates, fitted so the simulator reproduces the paper's *measured*
//!    anchor points (Table 9 BF16-NCCL and INT8 columns). These play the
//!    role of the protocol-efficiency and kernel-throughput factors the
//!    authors measured implicitly on their testbed; every other cell of
//!    Tables 9/10 is then *predicted* by the model, which is what we
//!    compare for shape.
//!
//! Calibration anchors (Table 9):
//!   L40 ring BF16 ≈ 10.43 GB/s  → bridge ≈ 18–19 GB/s effective
//!   A100/H800/H20 ring BF16 ≈ 89.15 / 94.18 / 209.14
//!       → effective NVLink ≈ 1.75 × those (ring moves 2(N−1)/N ≈ 1.75 M
//!         over the busiest link)
//!   INT8 two-step columns → per-device QDQ pass rates.

use super::{GpuSpec, Interconnect, Topology, TopologyError};

/// QDQ pass rate model: `rate = kappa × bf16_tflops × comm_sms / sms`,
/// in element-passes per second. κ is fitted per device family (see above).
fn qdq_rate(tflops: f64, comm_sms: u32, sms: u32, kappa: f64) -> f64 {
    kappa * tflops * 1e12 * comm_sms as f64 / sms as f64
}

/// NVIDIA L40: PCIe node, 2 NUMA groups of 4, no NVLink.
pub fn l40() -> GpuSpec {
    GpuSpec {
        name: "L40",
        sms: 142,
        comm_sms: 48,
        nominal_bw_gbps: 64.0,
        bf16_tflops: 90.5,
        tensor_bf16_tflops: 181.0,
        interconnect: Interconnect::PcieNuma { pcie_gbps: 19.0, bridge_gbps: 18.9 },
        stage_latency_s: 15e-6,
        ring_eff: 1.0,
        a2a_eff: 1.0,
        qdq_pass_rate: qdq_rate(90.5, 48, 142, 0.049), // ≈1.5e12 passes/s
    }
}

/// NVIDIA A100: NVLink-8. Low CUDA-core BF16 throughput → heavier QDQ tax.
pub fn a100() -> GpuSpec {
    GpuSpec {
        name: "A100",
        sms: 108,
        comm_sms: 48,
        nominal_bw_gbps: 400.0,
        bf16_tflops: 19.5,
        tensor_bf16_tflops: 312.0,
        interconnect: Interconnect::NvLink { gbps: 230.0 },
        stage_latency_s: 2e-6,
        ring_eff: 0.704, // ring BF16 anchor 89.15 GB/s
        a2a_eff: 0.65,
        qdq_pass_rate: qdq_rate(19.5, 48, 108, 0.104), // ≈0.9e12
    }
}

/// NVIDIA H800: NVLink-8, strong CUDA cores → biggest quantization gains.
pub fn h800() -> GpuSpec {
    GpuSpec {
        name: "H800",
        sms: 132,
        comm_sms: 48,
        nominal_bw_gbps: 400.0,
        bf16_tflops: 67.0,
        tensor_bf16_tflops: 989.0,
        interconnect: Interconnect::NvLink { gbps: 212.0 },
        stage_latency_s: 2e-6,
        ring_eff: 0.81, // ring BF16 anchor 94.18 GB/s
        a2a_eff: 0.70,
        qdq_pass_rate: qdq_rate(67.0, 48, 132, 0.049), // ≈1.2e12
    }
}

/// NVIDIA H20: NVLink-18 (900 GB/s) but weak compute — the regime where
/// quantization stops paying (paper: least gain, INT2_SR loses).
pub fn h20() -> GpuSpec {
    GpuSpec {
        name: "H20",
        sms: 78,
        comm_sms: 78, // the paper uses all SMs on H20
        nominal_bw_gbps: 900.0,
        bf16_tflops: 44.0,
        tensor_bf16_tflops: 148.0,
        interconnect: Interconnect::NvLink { gbps: 450.0 },
        stage_latency_s: 2e-6,
        ring_eff: 0.89, // ring BF16 anchor 209.14 GB/s
        a2a_eff: 0.77,
        qdq_pass_rate: qdq_rate(44.0, 78, 78, 0.024), // ≈1.05e12
    }
}

/// All presets, in the paper's Table 6 order.
pub fn all() -> Vec<GpuSpec> {
    vec![l40(), a100(), h800(), h20()]
}

/// Look up a preset by (case-insensitive) name.
pub fn by_name(name: &str) -> Option<GpuSpec> {
    all().into_iter().find(|s| s.name.eq_ignore_ascii_case(name))
}

// --- Topology presets (scenario sweeps beyond the paper's two shapes) ----

/// Effective bandwidth of a bonded inter-node fabric (GB/s) for the
/// [`dual_nvlink_node`] cluster — roughly 2×HDR InfiniBand / 4×100 GbE
/// after protocol derating, the regime SDP4Bit targets.
pub const INTER_NODE_GBPS: f64 = 25.0;

/// A 4-group PCIe chassis: L40-class devices in four NUMA groups joined by
/// the same class of bridge as the paper's 2-group box. Opens the
/// hierarchical family at `G = 4`.
pub fn four_group_pcie(n_gpus: usize) -> Result<Topology, TopologyError> {
    Topology::try_with_groups(l40(), n_gpus, 4)
}

/// Two NVLink-8 nodes joined by a slow inter-node link: intra-group NVLink
/// at H800 effective bandwidth, cross-group at [`INTER_NODE_GBPS`]. The
/// multi-node shape where the hierarchical two-step pays off on *flat*
/// intra-node fabrics.
pub fn dual_nvlink_node(n_gpus: usize) -> Result<Topology, TopologyError> {
    Topology::try_custom(h800(), n_gpus, 2, Some(INTER_NODE_GBPS * 1e9))
}

/// Named topology presets for benches and the CLI: the paper's two shapes
/// plus the generalized-G scenarios.
pub fn topology_by_name(name: &str, n_gpus: usize) -> Result<Topology, TopologyError> {
    match name.to_ascii_lowercase().as_str() {
        "l40" => Topology::try_new(l40(), n_gpus),
        "l40x4" | "pcie4" => four_group_pcie(n_gpus),
        "h800x2" | "duo" => dual_nvlink_node(n_gpus),
        other => match by_name(other) {
            Some(spec) => Topology::try_new(spec, n_gpus),
            None => Err(TopologyError::UnknownPreset { name: other.to_string() }),
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup_by_name() {
        assert_eq!(by_name("h800").unwrap().name, "H800");
        assert_eq!(by_name("L40").unwrap().name, "L40");
        assert!(by_name("B200").is_none());
    }

    #[test]
    fn h20_uses_all_sms() {
        let s = h20();
        assert_eq!(s.comm_sms, s.sms);
    }

    #[test]
    fn qdq_rates_ordered_by_cuda_capacity_within_family() {
        // H800 must out-rate A100 (the paper's explanation for its larger
        // speedup), both at 48 comm SMs.
        assert!(h800().qdq_pass_rate > a100().qdq_pass_rate);
    }

    #[test]
    fn topology_presets_open_the_new_scenarios() {
        let quad = four_group_pcie(8).unwrap();
        assert_eq!((quad.numa_groups, quad.group_size()), (4, 2));
        assert_eq!(quad.inter_bw(), l40().bridge_bw());

        let duo = dual_nvlink_node(16).unwrap();
        assert_eq!((duo.numa_groups, duo.group_size()), (2, 8));
        assert_eq!(duo.inter_bw(), Some(INTER_NODE_GBPS * 1e9));
        // The inter-node link is far slower than intra-node NVLink — the
        // regime where the hierarchical family pays off on flat fabrics.
        assert!(duo.inter_bw().unwrap() < duo.spec.intra_bw() / 4.0);

        assert!(four_group_pcie(6).is_err(), "6 GPUs don't split into 4 groups");
    }

    #[test]
    fn topology_lookup_by_name() {
        assert_eq!(topology_by_name("h800", 8).unwrap().numa_groups, 1);
        assert_eq!(topology_by_name("L40", 8).unwrap().numa_groups, 2);
        assert_eq!(topology_by_name("l40x4", 8).unwrap().numa_groups, 4);
        assert_eq!(topology_by_name("h800x2", 16).unwrap().group_size(), 8);
        let e = topology_by_name("b200", 8).unwrap_err();
        assert!(e.to_string().contains("unknown topology preset"), "{e}");
    }
}
