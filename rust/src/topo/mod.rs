//! Device and node topology model.
//!
//! [`GpuSpec`] captures the Table 6 device parameters (SM count,
//! interconnect class, bandwidth, CUDA-core BF16 compute) plus the
//! calibration constants that turn nominal link bandwidth into the
//! *effective* bandwidth collective traffic actually achieves (protocol
//! overhead, small-message inefficiency — the gap between 400 GB/s NVLink
//! and the ~90 GB/s NCCL BF16 algorithmic bandwidth the paper measures).
//!
//! [`Topology`] describes one multi-GPU system: `n_gpus` devices split into
//! `numa_groups` equal link-tier groups. A flat NVLink node is one group;
//! the paper's L40 box is two PCIe groups joined by a NUMA bridge
//! (Figs. 6–7); a 4-group PCIe chassis or two NVLink nodes joined by a slow
//! inter-node link are the same model at other `G` — the inter-group link
//! is explicit ([`Topology::inter_bw`]), so the hierarchical collectives
//! and the cost model generalize over `G` instead of hard-coding the pair
//! exchange. Construction is fallible ([`Topology::try_new`]): hostile or
//! mistyped shape arguments (CLI `--gpus`/`--groups`) produce a typed
//! [`TopologyError`], never a panic.

pub mod presets;

use std::fmt;

/// Physical interconnect of a node.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Interconnect {
    /// PCIe within NUMA groups; groups joined by NUMA bridges (L40/L20).
    PcieNuma {
        /// Effective per-GPU PCIe bandwidth within a group (GB/s).
        pcie_gbps: f64,
        /// Effective NUMA-bridge bandwidth shared by a group pair (GB/s).
        bridge_gbps: f64,
    },
    /// All-to-all NVLink (A100/H800/H20).
    NvLink {
        /// Effective per-GPU NVLink bandwidth (GB/s).
        gbps: f64,
    },
}

/// One device model (Table 6 row + calibration).
#[derive(Debug, Clone, PartialEq)]
pub struct GpuSpec {
    pub name: &'static str,
    /// Total streaming multiprocessors.
    pub sms: u32,
    /// SMs the fused QDQ kernel occupies (48 except H20: all 78).
    pub comm_sms: u32,
    /// Nominal interconnect bandwidth from Table 6 (GB/s).
    pub nominal_bw_gbps: f64,
    /// CUDA-core BF16 throughput (TFLOP/s) — what QDQ runs on.
    pub bf16_tflops: f64,
    /// Tensor-core dense BF16 throughput (TFLOP/s) — what prefill GEMMs
    /// run on (used by the TTFT model, not by the QDQ cost model).
    pub tensor_bf16_tflops: f64,
    /// Effective link model after protocol/calibration derating.
    pub interconnect: Interconnect,
    /// Per-hop launch/sync latency (s) for one collective stage.
    pub stage_latency_s: f64,
    /// Ring-protocol efficiency relative to the one-shot effective link
    /// bandwidth (NCCL's 2(N-1)-step ring realizes less of the fabric than
    /// a one-shot exchange; calibrated from the BF16 anchors).
    pub ring_eff: f64,
    /// All2All efficiency relative to the one-shot effective bandwidth.
    pub a2a_eff: f64,
    /// QDQ throughput at full comm-SM occupancy, in "element-passes" per
    /// second (one pass = read+process one bf16 element once). Derived
    /// from `bf16_tflops × comm_sms/sms × KAPPA` — see presets.rs.
    pub qdq_pass_rate: f64,
}

impl GpuSpec {
    /// Effective bandwidth of the flat interconnect (NVLink) or intra-group
    /// PCIe for NUMA systems, in bytes/s.
    pub fn intra_bw(&self) -> f64 {
        match self.interconnect {
            Interconnect::PcieNuma { pcie_gbps, .. } => pcie_gbps * 1e9,
            Interconnect::NvLink { gbps } => gbps * 1e9,
        }
    }

    /// Effective cross-NUMA bridge bandwidth in bytes/s (None on NVLink).
    pub fn bridge_bw(&self) -> Option<f64> {
        match self.interconnect {
            Interconnect::PcieNuma { bridge_gbps, .. } => Some(bridge_gbps * 1e9),
            Interconnect::NvLink { .. } => None,
        }
    }

    pub fn is_numa(&self) -> bool {
        matches!(self.interconnect, Interconnect::PcieNuma { .. })
    }
}

/// Why a topology could not be constructed. Surfaced (via
/// `CommError`/`anyhow`) for hostile or mistyped shape arguments — e.g.
/// `flashcomm train --gpus 6` against a 4-group layout — instead of the
/// panic the old `Topology::new` assert produced.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TopologyError {
    /// Collectives need at least two ranks.
    TooFewGpus { n_gpus: usize },
    /// A topology has at least one group.
    ZeroGroups,
    /// Groups must be equal: `n_gpus` must divide evenly into `groups`.
    Indivisible { n_gpus: usize, groups: usize },
    /// A multi-group topology needs an inter-group link model; this device
    /// spec defines none and no explicit bandwidth was supplied.
    NoInterGroupLink { spec: &'static str, groups: usize },
    /// No device or topology preset answers to this name.
    UnknownPreset { name: String },
}

impl fmt::Display for TopologyError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TopologyError::TooFewGpus { n_gpus } => {
                write!(f, "a topology needs at least 2 GPUs, got {n_gpus}")
            }
            TopologyError::ZeroGroups => write!(f, "a topology needs at least 1 group"),
            TopologyError::Indivisible { n_gpus, groups } => write!(
                f,
                "{n_gpus} GPUs cannot be split into {groups} equal groups \
                 ({n_gpus} % {groups} != 0)"
            ),
            TopologyError::NoInterGroupLink { spec, groups } => write!(
                f,
                "{spec} defines no inter-group link, so a {groups}-group topology needs \
                 an explicit inter-group bandwidth (Topology::try_custom)"
            ),
            TopologyError::UnknownPreset { name } => {
                write!(f, "unknown topology preset '{name}' (try l40|a100|h800|h20|l40x4|h800x2)")
            }
        }
    }
}

impl std::error::Error for TopologyError {}

/// A multi-GPU topology: `n_gpus` devices in `numa_groups` equal groups.
#[derive(Debug, Clone, PartialEq)]
pub struct Topology {
    pub spec: GpuSpec,
    pub n_gpus: usize,
    /// Number of link-tier groups (1 for flat NVLink systems, 2 for the
    /// paper's L40 box, arbitrary `G >= 1` in general).
    pub numa_groups: usize,
    /// Effective bandwidth (bytes/s) of the link joining adjacent groups;
    /// `None` exactly when `numa_groups == 1`. Read via
    /// [`Topology::inter_bw`].
    inter_group_bw: Option<f64>,
}

impl Topology {
    /// Default grouping for a device: 2 NUMA groups for PCIe/NUMA specs
    /// (the paper's box), 1 flat group for NVLink specs.
    pub fn try_new(spec: GpuSpec, n_gpus: usize) -> Result<Topology, TopologyError> {
        let groups = if spec.is_numa() { 2 } else { 1 };
        Topology::try_with_groups(spec, n_gpus, groups)
    }

    /// Explicit group count, with the inter-group link taken from the spec
    /// (the NUMA bridge). An NVLink spec with `groups > 1` is a
    /// [`TopologyError::NoInterGroupLink`] — use [`Topology::try_custom`]
    /// with an explicit inter-node bandwidth for multi-node clusters.
    pub fn try_with_groups(
        spec: GpuSpec,
        n_gpus: usize,
        groups: usize,
    ) -> Result<Topology, TopologyError> {
        let inter = if groups > 1 {
            match spec.bridge_bw() {
                Some(bw) => Some(bw),
                None => {
                    return Err(TopologyError::NoInterGroupLink { spec: spec.name, groups })
                }
            }
        } else {
            None
        };
        Topology::try_custom(spec, n_gpus, groups, inter)
    }

    /// Fully explicit construction: group count plus the effective
    /// bandwidth (bytes/s) of the inter-group link. This is how topologies
    /// the spec alone cannot describe are built — e.g. two NVLink nodes
    /// joined by a slow inter-node fabric
    /// ([`presets::dual_nvlink_node`]).
    pub fn try_custom(
        spec: GpuSpec,
        n_gpus: usize,
        groups: usize,
        inter_group_bw: Option<f64>,
    ) -> Result<Topology, TopologyError> {
        if groups == 0 {
            return Err(TopologyError::ZeroGroups);
        }
        if n_gpus < 2 {
            return Err(TopologyError::TooFewGpus { n_gpus });
        }
        if n_gpus % groups != 0 {
            return Err(TopologyError::Indivisible { n_gpus, groups });
        }
        if groups > 1 && inter_group_bw.is_none() {
            return Err(TopologyError::NoInterGroupLink { spec: spec.name, groups });
        }
        let inter_group_bw = if groups > 1 { inter_group_bw } else { None };
        Ok(Topology { spec, n_gpus, numa_groups: groups, inter_group_bw })
    }

    /// Panicking convenience over [`Topology::try_new`] for tests and
    /// hard-coded shapes. Anything driven by user input must use the
    /// fallible constructors.
    pub fn new(spec: GpuSpec, n_gpus: usize) -> Self {
        Topology::try_new(spec, n_gpus).expect("invalid hard-coded topology")
    }

    /// Panicking convenience over [`Topology::try_with_groups`] for tests.
    pub fn with_groups(spec: GpuSpec, n_gpus: usize, groups: usize) -> Self {
        Topology::try_with_groups(spec, n_gpus, groups).expect("invalid hard-coded topology")
    }

    /// Effective bandwidth (bytes/s) of the link joining adjacent groups;
    /// `None` exactly when the topology is flat (`numa_groups == 1`).
    pub fn inter_bw(&self) -> Option<f64> {
        self.inter_group_bw
    }

    /// Ranks per NUMA group.
    pub fn group_size(&self) -> usize {
        self.n_gpus / self.numa_groups
    }

    /// NUMA group of a rank.
    pub fn group_of(&self, rank: usize) -> usize {
        rank / self.group_size()
    }

    /// The rank in `group` that shares `rank`'s within-group index — its
    /// peer on the cross-group *column* `{g·s + j | g in 0..G}` the
    /// hierarchical cross-reduce rings over.
    pub fn peer_in_group(&self, rank: usize, group: usize) -> usize {
        debug_assert!(group < self.numa_groups);
        group * self.group_size() + rank % self.group_size()
    }

    /// The column peer one group over (ring order). At `G = 2` this is the
    /// symmetric cross-NUMA bridge pair of Fig. 7 (GPU i <-> GPU i + s).
    pub fn bridge_peer(&self, rank: usize) -> usize {
        (rank + self.group_size()) % self.n_gpus
    }

    /// All ranks in the same group as `rank`.
    pub fn group_members(&self, rank: usize) -> std::ops::Range<usize> {
        let g = self.group_of(rank);
        let s = self.group_size();
        g * s..(g + 1) * s
    }

    /// This topology with measured effective rates substituted for the
    /// static calibration: `intra_bw`/`inter_bw` in bytes/s,
    /// `qdq_pass_rate` in element-passes/s; `None` leaves a term
    /// untouched. This is the back door
    /// [`crate::sim::MeasuredProfile::apply`] uses for profile-guided plan
    /// recalibration — the shape (ranks, groups) is preserved, only the
    /// priced rates move, and [`Topology::fingerprint`] changes with them
    /// so plan-cache entries for the static topology are never reused.
    /// An `inter_bw` override on a flat (single-group) topology is
    /// ignored: there is no inter-group link to recalibrate.
    pub fn recalibrated(
        &self,
        intra_bw: Option<f64>,
        inter_bw: Option<f64>,
        qdq_pass_rate: Option<f64>,
    ) -> Topology {
        let mut spec = self.spec.clone();
        if let Some(bw) = intra_bw {
            spec.interconnect = match spec.interconnect {
                Interconnect::PcieNuma { bridge_gbps, .. } => {
                    Interconnect::PcieNuma { pcie_gbps: bw / 1e9, bridge_gbps }
                }
                Interconnect::NvLink { .. } => Interconnect::NvLink { gbps: bw / 1e9 },
            };
        }
        if let Some(rate) = qdq_pass_rate {
            spec.qdq_pass_rate = rate;
        }
        let inter_group_bw = if self.numa_groups > 1 {
            inter_bw.or(self.inter_group_bw)
        } else {
            None
        };
        Topology { spec, n_gpus: self.n_gpus, numa_groups: self.numa_groups, inter_group_bw }
    }

    /// FNV-1a fingerprint of every field the cost model prices: the spec's
    /// name and calibration constants (bandwidths, latency, QDQ pass rate,
    /// protocol efficiencies) plus the shape (`n_gpus`, `numa_groups`,
    /// inter-group bandwidth). Equal fingerprints price identically, which
    /// is what lets the plan cache key on this `u64` instead of cloning
    /// the whole topology.
    pub fn fingerprint(&self) -> u64 {
        const FNV_OFFSET: u64 = 0xcbf29ce484222325;
        const FNV_PRIME: u64 = 0x100000001b3;
        let mut h = FNV_OFFSET;
        let mut eat = |bytes: &[u8]| {
            for &b in bytes {
                h ^= b as u64;
                h = h.wrapping_mul(FNV_PRIME);
            }
        };
        eat(self.spec.name.as_bytes());
        eat(&(self.n_gpus as u64).to_le_bytes());
        eat(&(self.numa_groups as u64).to_le_bytes());
        eat(&self.inter_group_bw.unwrap_or(-1.0).to_bits().to_le_bytes());
        eat(&self.spec.intra_bw().to_bits().to_le_bytes());
        eat(&self.spec.stage_latency_s.to_bits().to_le_bytes());
        eat(&self.spec.qdq_pass_rate.to_bits().to_le_bytes());
        eat(&self.spec.ring_eff.to_bits().to_le_bytes());
        eat(&self.spec.a2a_eff.to_bits().to_le_bytes());
        h
    }
}

#[cfg(test)]
mod tests {
    use super::presets::*;
    use super::*;

    #[test]
    fn table6_constants() {
        // The paper's Table 6, verbatim.
        let rows = [
            (l40(), 142u32, 64.0, 90.5, 48u32),
            (a100(), 108, 400.0, 19.5, 48),
            (h800(), 132, 400.0, 67.0, 48),
            (h20(), 78, 900.0, 44.0, 78),
        ];
        for (spec, sms, bw, tflops, comm_sms) in rows {
            assert_eq!(spec.sms, sms, "{}", spec.name);
            assert_eq!(spec.nominal_bw_gbps, bw, "{}", spec.name);
            assert_eq!(spec.bf16_tflops, tflops, "{}", spec.name);
            assert_eq!(spec.comm_sms, comm_sms, "{}", spec.name);
        }
    }

    #[test]
    fn l40_is_numa_others_flat() {
        assert!(l40().is_numa());
        for s in [a100(), h800(), h20()] {
            assert!(!s.is_numa(), "{}", s.name);
            assert!(s.bridge_bw().is_none());
        }
    }

    #[test]
    fn numa_grouping() {
        let t = Topology::new(l40(), 8);
        assert_eq!(t.numa_groups, 2);
        assert_eq!(t.group_size(), 4);
        assert_eq!(t.group_of(3), 0);
        assert_eq!(t.group_of(4), 1);
        assert_eq!(t.bridge_peer(1), 5);
        assert_eq!(t.bridge_peer(5), 1);
        assert_eq!(t.group_members(6), 4..8);
        assert_eq!(t.inter_bw(), l40().bridge_bw());
    }

    #[test]
    fn four_group_topology() {
        let t = Topology::with_groups(l40(), 8, 4);
        assert_eq!(t.numa_groups, 4);
        assert_eq!(t.group_size(), 2);
        assert_eq!(t.group_of(5), 2);
        assert_eq!(t.group_members(5), 4..6);
        // Column of rank 5 (within-group index 1): {1, 3, 5, 7}.
        for (g, peer) in [(0usize, 1usize), (1, 3), (2, 5), (3, 7)] {
            assert_eq!(t.peer_in_group(5, g), peer);
        }
        // bridge_peer is the next group's column peer.
        assert_eq!(t.bridge_peer(5), 7);
        assert_eq!(t.bridge_peer(7), 1);
    }

    #[test]
    fn nvlink_single_group() {
        let t = Topology::new(h800(), 8);
        assert_eq!(t.numa_groups, 1);
        assert_eq!(t.group_size(), 8);
        assert_eq!(t.group_of(7), 0);
        assert_eq!(t.inter_bw(), None);
    }

    #[test]
    fn hostile_shapes_are_typed_errors_not_panics() {
        // The CLI-reachable failure: --gpus not divisible by the grouping.
        assert_eq!(
            Topology::try_with_groups(l40(), 6, 4).unwrap_err(),
            TopologyError::Indivisible { n_gpus: 6, groups: 4 }
        );
        assert_eq!(
            Topology::try_new(l40(), 5).unwrap_err(),
            TopologyError::Indivisible { n_gpus: 5, groups: 2 }
        );
        assert_eq!(
            Topology::try_new(h800(), 1).unwrap_err(),
            TopologyError::TooFewGpus { n_gpus: 1 }
        );
        assert_eq!(
            Topology::try_with_groups(h800(), 8, 0).unwrap_err(),
            TopologyError::ZeroGroups
        );
        // NVLink spec has no bridge: multi-group needs an explicit link.
        assert_eq!(
            Topology::try_with_groups(h800(), 8, 2).unwrap_err(),
            TopologyError::NoInterGroupLink { spec: "H800", groups: 2 }
        );
        assert!(Topology::try_custom(h800(), 8, 2, Some(25e9)).is_ok());
        // Errors display a readable reason and convert into anyhow.
        let e: anyhow::Error = Topology::try_with_groups(l40(), 6, 4).unwrap_err().into();
        assert!(e.to_string().contains("equal groups"), "{e}");
    }

    #[test]
    fn group_count_can_equal_gpu_count() {
        // Degenerate groups of one: every rank is its own group; the
        // cross-group column is the whole machine.
        let t = Topology::with_groups(l40(), 4, 4);
        assert_eq!(t.group_size(), 1);
        assert_eq!(t.group_members(2), 2..3);
        assert_eq!(t.peer_in_group(2, 0), 0);
    }

    #[test]
    fn fingerprint_separates_priced_shapes() {
        let l40 = Topology::new(l40(), 8);
        assert_eq!(l40.fingerprint(), Topology::new(super::presets::l40(), 8).fingerprint());
        assert_eq!(l40.fingerprint(), l40.clone().fingerprint());
        let mut seen = std::collections::HashSet::new();
        for t in [
            l40.clone(),
            Topology::new(h800(), 8),
            Topology::new(h800(), 16),
            Topology::with_groups(super::presets::l40(), 8, 4),
            Topology::try_custom(h800(), 8, 2, Some(25e9)).unwrap(),
            Topology::try_custom(h800(), 8, 2, Some(50e9)).unwrap(),
        ] {
            assert!(seen.insert(t.fingerprint()), "collision for {}x{}", t.spec.name, t.numa_groups);
        }
    }

    #[test]
    fn recalibration_moves_only_the_priced_rates() {
        let t = Topology::new(l40(), 8);
        let r = t.recalibrated(Some(30e9), Some(4e9), Some(1e12));
        assert_eq!(r.n_gpus, t.n_gpus);
        assert_eq!(r.numa_groups, t.numa_groups);
        assert_eq!(r.spec.intra_bw(), 30e9);
        assert_eq!(r.inter_bw(), Some(4e9));
        assert_eq!(r.spec.qdq_pass_rate, 1e12);
        assert_eq!(r.spec.ring_eff, t.spec.ring_eff, "unmeasured terms keep calibration");
        assert_ne!(r.fingerprint(), t.fingerprint());
        // None leaves each term untouched; a flat topology has no inter
        // link to override.
        assert_eq!(t.recalibrated(None, None, None), t);
        let flat = Topology::new(h800(), 8);
        assert_eq!(flat.recalibrated(None, Some(9e9), None).inter_bw(), None);
    }

    #[test]
    fn effective_bw_below_nominal() {
        for s in [l40(), a100(), h800(), h20()] {
            assert!(
                s.intra_bw() < s.nominal_bw_gbps * 1e9,
                "{}: effective must be derated below nominal",
                s.name
            );
        }
    }
}
