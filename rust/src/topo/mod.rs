//! Device and node topology model.
//!
//! [`GpuSpec`] captures the Table 6 device parameters (SM count,
//! interconnect class, bandwidth, CUDA-core BF16 compute) plus the
//! calibration constants that turn nominal link bandwidth into the
//! *effective* bandwidth collective traffic actually achieves (protocol
//! overhead, small-message inefficiency — the gap between 400 GB/s NVLink
//! and the ~90 GB/s NCCL BF16 algorithmic bandwidth the paper measures).
//!
//! [`Topology`] describes one node: `n_gpus` devices, optionally split into
//! NUMA groups bridged by a slower shared link (the L40 case, Figs. 6–7).

pub mod presets;

/// Physical interconnect of a node.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Interconnect {
    /// PCIe within NUMA groups; groups joined by NUMA bridges (L40/L20).
    PcieNuma {
        /// Effective per-GPU PCIe bandwidth within a group (GB/s).
        pcie_gbps: f64,
        /// Effective NUMA-bridge bandwidth shared by a group pair (GB/s).
        bridge_gbps: f64,
    },
    /// All-to-all NVLink (A100/H800/H20).
    NvLink {
        /// Effective per-GPU NVLink bandwidth (GB/s).
        gbps: f64,
    },
}

/// One device model (Table 6 row + calibration).
#[derive(Debug, Clone, PartialEq)]
pub struct GpuSpec {
    pub name: &'static str,
    /// Total streaming multiprocessors.
    pub sms: u32,
    /// SMs the fused QDQ kernel occupies (48 except H20: all 78).
    pub comm_sms: u32,
    /// Nominal interconnect bandwidth from Table 6 (GB/s).
    pub nominal_bw_gbps: f64,
    /// CUDA-core BF16 throughput (TFLOP/s) — what QDQ runs on.
    pub bf16_tflops: f64,
    /// Tensor-core dense BF16 throughput (TFLOP/s) — what prefill GEMMs
    /// run on (used by the TTFT model, not by the QDQ cost model).
    pub tensor_bf16_tflops: f64,
    /// Effective link model after protocol/calibration derating.
    pub interconnect: Interconnect,
    /// Per-hop launch/sync latency (s) for one collective stage.
    pub stage_latency_s: f64,
    /// Ring-protocol efficiency relative to the one-shot effective link
    /// bandwidth (NCCL's 2(N-1)-step ring realizes less of the fabric than
    /// a one-shot exchange; calibrated from the BF16 anchors).
    pub ring_eff: f64,
    /// All2All efficiency relative to the one-shot effective bandwidth.
    pub a2a_eff: f64,
    /// QDQ throughput at full comm-SM occupancy, in "element-passes" per
    /// second (one pass = read+process one bf16 element once). Derived
    /// from `bf16_tflops × comm_sms/sms × KAPPA` — see presets.rs.
    pub qdq_pass_rate: f64,
}

impl GpuSpec {
    /// Effective bandwidth of the flat interconnect (NVLink) or intra-group
    /// PCIe for NUMA systems, in bytes/s.
    pub fn intra_bw(&self) -> f64 {
        match self.interconnect {
            Interconnect::PcieNuma { pcie_gbps, .. } => pcie_gbps * 1e9,
            Interconnect::NvLink { gbps } => gbps * 1e9,
        }
    }

    /// Effective cross-NUMA bridge bandwidth in bytes/s (None on NVLink).
    pub fn bridge_bw(&self) -> Option<f64> {
        match self.interconnect {
            Interconnect::PcieNuma { bridge_gbps, .. } => Some(bridge_gbps * 1e9),
            Interconnect::NvLink { .. } => None,
        }
    }

    pub fn is_numa(&self) -> bool {
        matches!(self.interconnect, Interconnect::PcieNuma { .. })
    }
}

/// A single-node multi-GPU topology.
#[derive(Debug, Clone, PartialEq)]
pub struct Topology {
    pub spec: GpuSpec,
    pub n_gpus: usize,
    /// Number of NUMA groups (1 for NVLink systems).
    pub numa_groups: usize,
}

impl Topology {
    pub fn new(spec: GpuSpec, n_gpus: usize) -> Self {
        let numa_groups = if spec.is_numa() { 2 } else { 1 };
        assert!(n_gpus >= 2 && n_gpus % numa_groups == 0, "n_gpus {n_gpus} not divisible");
        Topology { spec, n_gpus, numa_groups }
    }

    /// Ranks per NUMA group.
    pub fn group_size(&self) -> usize {
        self.n_gpus / self.numa_groups
    }

    /// NUMA group of a rank.
    pub fn group_of(&self, rank: usize) -> usize {
        rank / self.group_size()
    }

    /// The rank in the other group paired with `rank` for cross-NUMA
    /// point-to-point reduction (Fig. 7: GPU i <-> GPU i + group_size).
    pub fn bridge_peer(&self, rank: usize) -> usize {
        debug_assert_eq!(self.numa_groups, 2);
        (rank + self.group_size()) % self.n_gpus
    }

    /// All ranks in the same group as `rank`.
    pub fn group_members(&self, rank: usize) -> std::ops::Range<usize> {
        let g = self.group_of(rank);
        let s = self.group_size();
        g * s..(g + 1) * s
    }
}

#[cfg(test)]
mod tests {
    use super::presets::*;
    use super::*;

    #[test]
    fn table6_constants() {
        // The paper's Table 6, verbatim.
        let rows = [
            (l40(), 142u32, 64.0, 90.5, 48u32),
            (a100(), 108, 400.0, 19.5, 48),
            (h800(), 132, 400.0, 67.0, 48),
            (h20(), 78, 900.0, 44.0, 78),
        ];
        for (spec, sms, bw, tflops, comm_sms) in rows {
            assert_eq!(spec.sms, sms, "{}", spec.name);
            assert_eq!(spec.nominal_bw_gbps, bw, "{}", spec.name);
            assert_eq!(spec.bf16_tflops, tflops, "{}", spec.name);
            assert_eq!(spec.comm_sms, comm_sms, "{}", spec.name);
        }
    }

    #[test]
    fn l40_is_numa_others_flat() {
        assert!(l40().is_numa());
        for s in [a100(), h800(), h20()] {
            assert!(!s.is_numa(), "{}", s.name);
            assert!(s.bridge_bw().is_none());
        }
    }

    #[test]
    fn numa_grouping() {
        let t = Topology::new(l40(), 8);
        assert_eq!(t.numa_groups, 2);
        assert_eq!(t.group_size(), 4);
        assert_eq!(t.group_of(3), 0);
        assert_eq!(t.group_of(4), 1);
        assert_eq!(t.bridge_peer(1), 5);
        assert_eq!(t.bridge_peer(5), 1);
        assert_eq!(t.group_members(6), 4..8);
    }

    #[test]
    fn nvlink_single_group() {
        let t = Topology::new(h800(), 8);
        assert_eq!(t.numa_groups, 1);
        assert_eq!(t.group_size(), 8);
        assert_eq!(t.group_of(7), 0);
    }

    #[test]
    fn effective_bw_below_nominal() {
        for s in [l40(), a100(), h800(), h20()] {
            assert!(
                s.intra_bw() < s.nominal_bw_gbps * 1e9,
                "{}: effective must be derated below nominal",
                s.name
            );
        }
    }
}
