//! The communication fabric: rank endpoints over a pluggable transport.
//!
//! Stands in for the GPU interconnect: N ranks exchange byte payloads over
//! a [`Transport`] backend — mpsc channels for in-process thread ranks
//! ([`run_ranks`]), real sockets for multi-process ranks (the `worker`
//! CLI / [`crate::transport::tcp`]). The collectives built on top move
//! *real encoded bytes* through it — quantize → bit-split pack → transfer →
//! unpack → dequantize → reduce — so functional behaviour (numerics, wire
//! format, QDQ placement) is exactly the paper's; only the physical
//! transport differs (see DESIGN.md §2). Per-link-class byte counters let
//! tests verify the Table 5 volume accounting against the closed forms.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::comm::error::CommError;
use crate::session::find_peer_lost;
use crate::telemetry::{Kind, Op, Recorder};
use crate::topo::Topology;
use crate::transport::{inproc, InProcTransport, Transport};

/// Byte counters, split by link class (Table 5 columns). Counts *payload*
/// bytes (the collective's semantic volume); per-frame transport overhead
/// is visible through [`Transport::stats`] instead.
///
/// Counters are *monotone*: they only ever climb. There is deliberately no
/// reset — a reset racing a concurrent `send` could tear the totals (bytes
/// wiped but their message counted, or vice versa). Readers that want
/// per-window accounting take a [`ByteCounters::snapshot`] as their epoch
/// baseline and diff later snapshots against it with
/// [`CountersSnapshot::since`].
#[derive(Debug, Default)]
pub struct ByteCounters {
    /// All bytes that crossed any link.
    pub total: AtomicU64,
    /// Bytes that crossed the NUMA bridge (src and dst in different groups).
    pub cross_numa: AtomicU64,
    /// Number of point-to-point messages.
    pub messages: AtomicU64,
}

/// A point-in-time copy of [`ByteCounters`], coherent when taken at rest.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CountersSnapshot {
    pub total: u64,
    pub cross_numa: u64,
    pub messages: u64,
}

impl ByteCounters {
    pub fn total_bytes(&self) -> u64 {
        self.total.load(Ordering::Relaxed)
    }

    pub fn cross_numa_bytes(&self) -> u64 {
        self.cross_numa.load(Ordering::Relaxed)
    }

    pub fn message_count(&self) -> u64 {
        self.messages.load(Ordering::Relaxed)
    }

    /// Copy all three counters at once.
    ///
    /// The three loads are individually relaxed — the copy is mutually
    /// consistent only when no collective is in flight (e.g. after
    /// [`run_ranks`] returned). Tests should compare snapshots taken at
    /// rest instead of reading individual counters around live traffic.
    pub fn snapshot(&self) -> CountersSnapshot {
        CountersSnapshot {
            total: self.total_bytes(),
            cross_numa: self.cross_numa_bytes(),
            messages: self.message_count(),
        }
    }

}

impl CountersSnapshot {
    /// The traffic between `epoch` and `self` — the epoch/delta scheme
    /// that replaces the old racy `reset()`: instead of zeroing shared
    /// atomics (which could interleave with a concurrent `send` and leave
    /// readers with torn totals), each reader keeps its own immutable
    /// baseline and subtracts. `wrapping_sub` keeps even a stale baseline
    /// from panicking in debug builds.
    pub fn since(&self, epoch: &CountersSnapshot) -> CountersSnapshot {
        CountersSnapshot {
            total: self.total.wrapping_sub(epoch.total),
            cross_numa: self.cross_numa.wrapping_sub(epoch.cross_numa),
            messages: self.messages.wrapping_sub(epoch.messages),
        }
    }
}

/// One rank's endpoint into the fabric: a connected transport plus the
/// node topology and shared byte accounting. Generic over the backend;
/// defaults to the in-process mesh so existing signatures keep reading
/// `&RankHandle`.
pub struct RankHandle<T: Transport = InProcTransport> {
    pub rank: usize,
    pub n: usize,
    topo: Topology,
    transport: T,
    counters: Arc<ByteCounters>,
    /// Optional flight recorder ([`crate::telemetry`]). `None` (the
    /// default) keeps the hot path at a single untaken branch per
    /// send/recv.
    recorder: Option<Arc<Recorder>>,
    /// Per-destination ordinal of *recorded* sends. Because recording is
    /// enabled before any collective traffic (and the transports are
    /// per-link FIFO), ordinal `q` on this side's link to `dst` names the
    /// same message as ordinal `q` of `dst`'s receives from us — the
    /// identity the fabric trace merge uses to draw send→recv flow
    /// arrows (DESIGN.md §15). Untouched when no recorder is installed.
    send_seq: Vec<AtomicU64>,
    /// Per-source ordinal of recorded receives (see `send_seq`).
    recv_seq: Vec<AtomicU64>,
}

impl<T: Transport> RankHandle<T> {
    /// Wrap a connected transport endpoint. `topo` must describe the same
    /// world size the transport was bootstrapped with; `counters` is shared
    /// across every handle of the same logical job (one per process for
    /// multi-process transports).
    pub fn new(transport: T, topo: Topology, counters: Arc<ByteCounters>) -> RankHandle<T> {
        assert_eq!(
            topo.n_gpus,
            transport.n(),
            "topology is {} ranks but the transport mesh has {}",
            topo.n_gpus,
            transport.n()
        );
        let n = transport.n();
        RankHandle {
            rank: transport.rank(),
            n,
            topo,
            transport,
            counters,
            recorder: None,
            send_seq: (0..n).map(|_| AtomicU64::new(0)).collect(),
            recv_seq: (0..n).map(|_| AtomicU64::new(0)).collect(),
        }
    }

    /// Install (or remove) a flight recorder. Every subsequent
    /// [`RankHandle::send`]/[`RankHandle::recv`] records a `Send`/`Recv`
    /// span — this one hook instruments every transport backend uniformly,
    /// since all collective traffic funnels through the handle.
    pub fn set_recorder(&mut self, recorder: Option<Arc<Recorder>>) {
        self.recorder = recorder;
    }

    /// The installed flight recorder, if any — the `record!` gate the
    /// collectives use for their encode/decode spans and stage context.
    pub fn recorder(&self) -> Option<&Recorder> {
        self.recorder.as_deref()
    }

    /// Send a payload to `dst` (non-blocking with respect to the peer's
    /// progress; see [`Transport`]). A transport fault surfaces as
    /// [`CommError::Send`] — no panic — except a session-declared peer
    /// death, which surfaces as the typed [`CommError::PeerLost`].
    pub fn send(&self, dst: usize, bytes: Vec<u8>) -> Result<(), CommError> {
        assert_ne!(dst, self.rank, "self-send is a local copy, not a transfer");
        self.counters.total.fetch_add(bytes.len() as u64, Ordering::Relaxed);
        self.counters.messages.fetch_add(1, Ordering::Relaxed);
        if self.topo.numa_groups > 1 && self.topo.group_of(self.rank) != self.topo.group_of(dst) {
            self.counters.cross_numa.fetch_add(bytes.len() as u64, Ordering::Relaxed);
        }
        let len = bytes.len() as u64;
        // Link-stamped Send span: same two events as before (the pinned
        // per-rank counts must not move), now carrying (dst, ordinal) so
        // the trace merge can pair this send with the peer's recv.
        let link = self.recorder().map(|rec| {
            let q = self.send_seq[dst].fetch_add(1, Ordering::Relaxed);
            rec.record_link(Kind::Start, Op::Send, len, dst as u16, q);
            q
        });
        let sent = self.transport.send(dst, bytes).map_err(|e| self.classify(dst, e, true));
        if let (Some(rec), Some(q)) = (self.recorder(), link) {
            rec.record_link(Kind::End, Op::Send, len, dst as u16, q);
        }
        sent
    }

    /// Block until a payload from `src` arrives. A transport fault
    /// (corruption, version mismatch, sequence desync, disconnect) surfaces
    /// as [`CommError::Recv`] — a collective cannot continue past a broken
    /// link, but the caller decides how loudly to fail. A peer the session
    /// fabric declared dead surfaces as the typed [`CommError::PeerLost`]
    /// instead, so survivors can re-plan over the remaining membership.
    pub fn recv(&self, src: usize) -> Result<Vec<u8>, CommError> {
        assert_ne!(src, self.rank);
        let link = self.recorder().map(|rec| {
            let q = self.recv_seq[src].fetch_add(1, Ordering::Relaxed);
            rec.record_link(Kind::Start, Op::Recv, 0, src as u16, q);
            q
        });
        let got = self.transport.recv(src).map_err(|e| self.classify(src, e, false));
        if let Ok(bytes) = &got {
            if let (Some(rec), Some(q)) = (self.recorder(), link) {
                rec.record_link(Kind::End, Op::Recv, bytes.len() as u64, src as u16, q);
            }
        }
        got
    }

    /// Map a transport error to the typed comm error: a [`PeerLost`]
    /// anywhere in the chain (planted by the session fabric or the fault
    /// injector) wins over the generic send/recv classification, and is
    /// recorded as an [`Op::PeerLost`] telemetry event.
    fn classify(&self, peer: usize, e: anyhow::Error, sending: bool) -> CommError {
        if let Some(lost) = find_peer_lost(&e) {
            // A loss is an instant, not a span: one Start event, bytes
            // field carrying the lost rank.
            crate::record!(self.recorder(), start Op::PeerLost, lost.rank as u64);
            return CommError::peer_lost(lost.rank, lost.epoch);
        }
        if sending {
            CommError::send(peer, e)
        } else {
            CommError::recv(peer, e)
        }
    }

    /// The node topology this fabric models.
    pub fn topo(&self) -> &Topology {
        &self.topo
    }

    /// Shared byte counters (same instance across all ranks of this job).
    pub fn counters(&self) -> &ByteCounters {
        &self.counters
    }

    /// The underlying transport endpoint (e.g. for [`Transport::stats`]).
    pub fn transport(&self) -> &T {
        &self.transport
    }

    /// Decompose the handle into its transport, topology, and counters —
    /// the membership-change path: after a peer loss, the transport is
    /// rewrapped in a [`crate::session::DegradedMesh`] and a new handle is
    /// built over the survivor topology (counters carry across, so the
    /// Table 5 volume accounting spans the loss).
    pub fn into_parts(self) -> (T, Topology, Arc<ByteCounters>) {
        (self.transport, self.topo, self.counters)
    }
}

/// Build an in-process fabric over `topo` and run `f` once per rank, each
/// on its own thread. Returns the per-rank results in rank order, plus the
/// counters.
pub fn run_ranks<R, F>(topo: &Topology, f: F) -> (Vec<R>, Arc<ByteCounters>)
where
    R: Send,
    F: Fn(RankHandle<InProcTransport>) -> R + Sync,
{
    run_ranks_with(inproc::mesh(topo.n_gpus), topo, f)
}

/// Run `f` once per rank over pre-connected transport endpoints (endpoint
/// `i` must be rank `i`), each on its own thread. This is how alternative
/// backends (e.g. [`crate::transport::tcp::local_mesh`]) drive the same
/// collectives the in-process fabric runs.
pub fn run_ranks_with<T, R, F>(endpoints: Vec<T>, topo: &Topology, f: F) -> (Vec<R>, Arc<ByteCounters>)
where
    T: Transport,
    R: Send,
    F: Fn(RankHandle<T>) -> R + Sync,
{
    assert_eq!(endpoints.len(), topo.n_gpus, "one endpoint per rank");
    let counters = Arc::new(ByteCounters::default());
    let handles: Vec<RankHandle<T>> = endpoints
        .into_iter()
        .enumerate()
        .map(|(i, t)| {
            assert_eq!(t.rank(), i, "endpoint {i} reports rank {}", t.rank());
            RankHandle::new(t, topo.clone(), counters.clone())
        })
        .collect();
    let results = std::thread::scope(|scope| {
        let mut joins = Vec::with_capacity(handles.len());
        for h in handles {
            let f = &f;
            joins.push(scope.spawn(move || f(h)));
        }
        // lint: allow(panic, "harness: a panicked rank must fail the whole run loudly")
        joins.into_iter().map(|j| j.join().expect("rank panicked")).collect::<Vec<R>>()
    });
    (results, counters)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topo::{presets, Topology};

    fn l40x8() -> Topology {
        Topology::new(presets::l40(), 8)
    }

    #[test]
    fn pairwise_exchange_delivers() {
        let topo = Topology::new(presets::h800(), 4);
        let (results, _) = run_ranks(&topo, |h| {
            // Everyone sends its rank byte to everyone.
            for d in 0..h.n {
                if d != h.rank {
                    h.send(d, vec![h.rank as u8]).unwrap();
                }
            }
            let mut got = Vec::new();
            for s in 0..h.n {
                if s != h.rank {
                    got.push(h.recv(s).unwrap()[0]);
                }
            }
            got
        });
        assert_eq!(results[0], vec![1, 2, 3]);
        assert_eq!(results[3], vec![0, 1, 2]);
    }

    #[test]
    fn counters_track_total_and_cross_numa() {
        let topo = l40x8();
        let (_, counters) = run_ranks(&topo, |h| {
            // One 100-byte message to the bridge peer (cross) and one to an
            // intra-group neighbour.
            let peer = h.topo().bridge_peer(h.rank);
            h.send(peer, vec![0u8; 100]).unwrap();
            let _ = h.recv(peer).unwrap();
            let g = h.topo().group_members(h.rank);
            let neighbour = if h.rank + 1 < g.end { h.rank + 1 } else { g.start };
            h.send(neighbour, vec![0u8; 10]).unwrap();
            let _ = h.recv(if h.rank > g.start { h.rank - 1 } else { g.end - 1 }).unwrap();
        });
        let snap = counters.snapshot();
        assert_eq!(snap.total, 8 * 110);
        assert_eq!(snap.cross_numa, 8 * 100);
        assert_eq!(snap.messages, 16);
    }

    #[test]
    fn messages_from_same_peer_arrive_in_order() {
        let topo = Topology::new(presets::h800(), 2);
        let (results, _) = run_ranks(&topo, |h| {
            if h.rank == 0 {
                for i in 0..100u8 {
                    h.send(1, vec![i]).unwrap();
                }
                Vec::new()
            } else {
                (0..100).map(|_| h.recv(0).unwrap()[0]).collect::<Vec<u8>>()
            }
        });
        assert_eq!(results[1], (0..100).collect::<Vec<u8>>());
    }

    #[test]
    fn snapshot_deltas_replace_reset_between_runs() {
        let topo = Topology::new(presets::h800(), 2);
        let (_, counters) = run_ranks(&topo, |h| {
            if h.rank == 0 {
                h.send(1, vec![0u8; 64]).unwrap();
            } else {
                let _ = h.recv(0).unwrap();
            }
        });
        // At rest, the snapshot is coherent; it becomes this reader's
        // epoch baseline. Counters stay monotone — a second measurement
        // window subtracts the baseline instead of resetting shared state
        // (the old `reset()` could tear totals under concurrent senders).
        let epoch = counters.snapshot();
        assert_eq!(epoch, CountersSnapshot { total: 64, cross_numa: 0, messages: 1 });
        counters.total.fetch_add(100, Ordering::Relaxed);
        counters.messages.fetch_add(2, Ordering::Relaxed);
        let delta = counters.snapshot().since(&epoch);
        assert_eq!(delta, CountersSnapshot { total: 100, cross_numa: 0, messages: 2 });
        // A reader with a fresh (zero) epoch sees lifetime totals.
        assert_eq!(counters.snapshot().since(&CountersSnapshot::default()).total, 164);
    }

    #[test]
    fn handles_record_send_and_recv_spans_when_enabled() {
        use crate::telemetry::{Kind, Recorder};
        let topo = Topology::new(presets::h800(), 2);
        let (recorders, _) = run_ranks(&topo, |mut h| {
            let rec = Arc::new(Recorder::new(h.rank, 64));
            h.set_recorder(Some(rec.clone()));
            if h.rank == 0 {
                h.send(1, vec![0u8; 48]).unwrap();
            } else {
                let _ = h.recv(0).unwrap();
            }
            rec
        });
        let sends = recorders[0].events();
        assert_eq!(sends.len(), 2);
        assert_eq!((sends[0].kind, sends[0].op), (Kind::Start, Op::Send));
        assert_eq!((sends[1].kind, sends[1].op), (Kind::End, Op::Send));
        assert_eq!(sends[1].bytes, 48);
        assert_eq!(sends[1].rank, 0);
        let recvs = recorders[1].events();
        assert_eq!(recvs.len(), 2);
        assert_eq!((recvs[0].kind, recvs[0].op), (Kind::Start, Op::Recv));
        assert_eq!(recvs[0].bytes, 0, "recv start cannot know the payload yet");
        assert_eq!((recvs[1].kind, recvs[1].op), (Kind::End, Op::Recv));
        assert_eq!(recvs[1].bytes, 48);
        // Link identity: send (0→1, ordinal 0) pairs with recv (from 0,
        // ordinal 0) — the flow-arrow key of the fabric trace merge.
        for e in &sends {
            assert_eq!(e.link, Some((1, 0)), "{e:?}");
        }
        for e in &recvs {
            assert_eq!(e.link, Some((0, 0)), "{e:?}");
        }
    }

    #[test]
    fn peer_loss_is_typed_and_recorded() {
        use crate::session::{fault, Fault};
        use crate::telemetry::Recorder;
        use std::time::Duration;
        let topo = Topology::new(presets::h800(), 2);
        // Rank 1 dies at its first send; rank 0's recv must come back as
        // the typed CommError::PeerLost plus one telemetry point event.
        let endpoints = fault::wrap_mesh(
            inproc::mesh(2),
            vec![Fault::None, Fault::KillAtSend { nth: 0 }],
            Duration::from_secs(5),
        );
        let (results, _) = run_ranks_with(endpoints, &topo, |mut h| {
            let rec = Arc::new(Recorder::new(h.rank, 16));
            h.set_recorder(Some(rec.clone()));
            if h.rank == 1 {
                let e = h.send(0, vec![1]).unwrap_err();
                (format!("{e}"), rec)
            } else {
                let e = h.recv(1).unwrap_err();
                (format!("{e}"), rec)
            }
        });
        for (msg, rec) in &results {
            assert!(msg.contains("PeerLost"), "{msg}");
            assert!(msg.contains("rank 1"), "{msg}");
            let events = rec.events();
            let loss = events.iter().find(|e| e.op == Op::PeerLost).expect("PeerLost event");
            assert_eq!(loss.bytes, 1, "bytes field carries the lost rank");
        }
    }

    #[test]
    fn transport_stats_include_frame_overhead() {
        use crate::transport::FRAME_HEADER_LEN;
        let topo = Topology::new(presets::h800(), 2);
        let (stats, counters) = run_ranks(&topo, |h| {
            if h.rank == 0 {
                h.send(1, vec![0u8; 100]).unwrap();
            } else {
                let _ = h.recv(0).unwrap();
            }
            h.transport().stats()
        });
        // InProc stats are mesh-shared; payload accounting matches the
        // comm-layer counters, wire accounting adds one frame header. (The
        // send-side counters are deterministic here — every send
        // happens-before both snapshots; the buffered gauge is not, since
        // rank 0 may snapshot while rank 1's recv is still pending.)
        for s in &stats {
            assert_eq!(s.payload_bytes, counters.total_bytes());
            assert_eq!(s.wire_bytes, counters.total_bytes() + FRAME_HEADER_LEN as u64);
            assert_eq!(s.messages, 1);
        }
    }
}
