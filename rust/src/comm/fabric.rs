//! The communication fabric: rank endpoints over a pluggable transport.
//!
//! Stands in for the GPU interconnect: N ranks exchange byte payloads over
//! a [`Transport`] backend — mpsc channels for in-process thread ranks
//! ([`run_ranks`]), real sockets for multi-process ranks (the `worker`
//! CLI / [`crate::transport::tcp`]). The collectives built on top move
//! *real encoded bytes* through it — quantize → bit-split pack → transfer →
//! unpack → dequantize → reduce — so functional behaviour (numerics, wire
//! format, QDQ placement) is exactly the paper's; only the physical
//! transport differs (see DESIGN.md §2). Per-link-class byte counters let
//! tests verify the Table 5 volume accounting against the closed forms.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::comm::error::CommError;
use crate::topo::Topology;
use crate::transport::{inproc, InProcTransport, Transport};

/// Byte counters, split by link class (Table 5 columns). Counts *payload*
/// bytes (the collective's semantic volume); per-frame transport overhead
/// is visible through [`Transport::stats`] instead.
#[derive(Debug, Default)]
pub struct ByteCounters {
    /// All bytes that crossed any link.
    pub total: AtomicU64,
    /// Bytes that crossed the NUMA bridge (src and dst in different groups).
    pub cross_numa: AtomicU64,
    /// Number of point-to-point messages.
    pub messages: AtomicU64,
}

/// A point-in-time copy of [`ByteCounters`], coherent when taken at rest.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CountersSnapshot {
    pub total: u64,
    pub cross_numa: u64,
    pub messages: u64,
}

impl ByteCounters {
    pub fn total_bytes(&self) -> u64 {
        self.total.load(Ordering::Relaxed)
    }

    pub fn cross_numa_bytes(&self) -> u64 {
        self.cross_numa.load(Ordering::Relaxed)
    }

    pub fn message_count(&self) -> u64 {
        self.messages.load(Ordering::Relaxed)
    }

    /// Copy all three counters at once.
    ///
    /// The three loads are individually relaxed — the copy is mutually
    /// consistent only when no collective is in flight (e.g. after
    /// [`run_ranks`] returned). Tests should compare snapshots taken at
    /// rest instead of reading individual counters around live traffic.
    pub fn snapshot(&self) -> CountersSnapshot {
        CountersSnapshot {
            total: self.total_bytes(),
            cross_numa: self.cross_numa_bytes(),
            messages: self.message_count(),
        }
    }

    /// Reset all counters to zero.
    ///
    /// This is three independent relaxed stores, **not** an atomic
    /// snapshot-and-clear: a `send` racing with `reset` can land between
    /// the stores and leave the counters mutually inconsistent (e.g.
    /// `messages` incremented but its bytes wiped). Only call while no
    /// collective is in flight — between [`run_ranks`] invocations — and
    /// read totals via [`ByteCounters::snapshot`] after ranks have joined.
    pub fn reset(&self) {
        self.total.store(0, Ordering::Relaxed);
        self.cross_numa.store(0, Ordering::Relaxed);
        self.messages.store(0, Ordering::Relaxed);
    }
}

/// One rank's endpoint into the fabric: a connected transport plus the
/// node topology and shared byte accounting. Generic over the backend;
/// defaults to the in-process mesh so existing signatures keep reading
/// `&RankHandle`.
pub struct RankHandle<T: Transport = InProcTransport> {
    pub rank: usize,
    pub n: usize,
    topo: Topology,
    transport: T,
    counters: Arc<ByteCounters>,
}

impl<T: Transport> RankHandle<T> {
    /// Wrap a connected transport endpoint. `topo` must describe the same
    /// world size the transport was bootstrapped with; `counters` is shared
    /// across every handle of the same logical job (one per process for
    /// multi-process transports).
    pub fn new(transport: T, topo: Topology, counters: Arc<ByteCounters>) -> RankHandle<T> {
        assert_eq!(
            topo.n_gpus,
            transport.n(),
            "topology is {} ranks but the transport mesh has {}",
            topo.n_gpus,
            transport.n()
        );
        RankHandle { rank: transport.rank(), n: transport.n(), topo, transport, counters }
    }

    /// Send a payload to `dst` (non-blocking with respect to the peer's
    /// progress; see [`Transport`]). A transport fault surfaces as
    /// [`CommError::Send`] — no panic.
    pub fn send(&self, dst: usize, bytes: Vec<u8>) -> Result<(), CommError> {
        assert_ne!(dst, self.rank, "self-send is a local copy, not a transfer");
        self.counters.total.fetch_add(bytes.len() as u64, Ordering::Relaxed);
        self.counters.messages.fetch_add(1, Ordering::Relaxed);
        if self.topo.numa_groups > 1 && self.topo.group_of(self.rank) != self.topo.group_of(dst) {
            self.counters.cross_numa.fetch_add(bytes.len() as u64, Ordering::Relaxed);
        }
        self.transport.send(dst, bytes).map_err(|e| CommError::send(dst, e))
    }

    /// Block until a payload from `src` arrives. A transport fault
    /// (corruption, version mismatch, sequence desync, disconnect) surfaces
    /// as [`CommError::Recv`] — a collective cannot continue past a broken
    /// link, but the caller decides how loudly to fail.
    pub fn recv(&self, src: usize) -> Result<Vec<u8>, CommError> {
        assert_ne!(src, self.rank);
        self.transport.recv(src).map_err(|e| CommError::recv(src, e))
    }

    /// The node topology this fabric models.
    pub fn topo(&self) -> &Topology {
        &self.topo
    }

    /// Shared byte counters (same instance across all ranks of this job).
    pub fn counters(&self) -> &ByteCounters {
        &self.counters
    }

    /// The underlying transport endpoint (e.g. for [`Transport::stats`]).
    pub fn transport(&self) -> &T {
        &self.transport
    }
}

/// Build an in-process fabric over `topo` and run `f` once per rank, each
/// on its own thread. Returns the per-rank results in rank order, plus the
/// counters.
pub fn run_ranks<R, F>(topo: &Topology, f: F) -> (Vec<R>, Arc<ByteCounters>)
where
    R: Send,
    F: Fn(RankHandle<InProcTransport>) -> R + Sync,
{
    run_ranks_with(inproc::mesh(topo.n_gpus), topo, f)
}

/// Run `f` once per rank over pre-connected transport endpoints (endpoint
/// `i` must be rank `i`), each on its own thread. This is how alternative
/// backends (e.g. [`crate::transport::tcp::local_mesh`]) drive the same
/// collectives the in-process fabric runs.
pub fn run_ranks_with<T, R, F>(endpoints: Vec<T>, topo: &Topology, f: F) -> (Vec<R>, Arc<ByteCounters>)
where
    T: Transport,
    R: Send,
    F: Fn(RankHandle<T>) -> R + Sync,
{
    assert_eq!(endpoints.len(), topo.n_gpus, "one endpoint per rank");
    let counters = Arc::new(ByteCounters::default());
    let handles: Vec<RankHandle<T>> = endpoints
        .into_iter()
        .enumerate()
        .map(|(i, t)| {
            assert_eq!(t.rank(), i, "endpoint {i} reports rank {}", t.rank());
            RankHandle::new(t, topo.clone(), counters.clone())
        })
        .collect();
    let results = std::thread::scope(|scope| {
        let mut joins = Vec::with_capacity(handles.len());
        for h in handles {
            let f = &f;
            joins.push(scope.spawn(move || f(h)));
        }
        joins.into_iter().map(|j| j.join().expect("rank panicked")).collect::<Vec<R>>()
    });
    (results, counters)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topo::{presets, Topology};

    fn l40x8() -> Topology {
        Topology::new(presets::l40(), 8)
    }

    #[test]
    fn pairwise_exchange_delivers() {
        let topo = Topology::new(presets::h800(), 4);
        let (results, _) = run_ranks(&topo, |h| {
            // Everyone sends its rank byte to everyone.
            for d in 0..h.n {
                if d != h.rank {
                    h.send(d, vec![h.rank as u8]).unwrap();
                }
            }
            let mut got = Vec::new();
            for s in 0..h.n {
                if s != h.rank {
                    got.push(h.recv(s).unwrap()[0]);
                }
            }
            got
        });
        assert_eq!(results[0], vec![1, 2, 3]);
        assert_eq!(results[3], vec![0, 1, 2]);
    }

    #[test]
    fn counters_track_total_and_cross_numa() {
        let topo = l40x8();
        let (_, counters) = run_ranks(&topo, |h| {
            // One 100-byte message to the bridge peer (cross) and one to an
            // intra-group neighbour.
            let peer = h.topo().bridge_peer(h.rank);
            h.send(peer, vec![0u8; 100]).unwrap();
            let _ = h.recv(peer).unwrap();
            let g = h.topo().group_members(h.rank);
            let neighbour = if h.rank + 1 < g.end { h.rank + 1 } else { g.start };
            h.send(neighbour, vec![0u8; 10]).unwrap();
            let _ = h.recv(if h.rank > g.start { h.rank - 1 } else { g.end - 1 }).unwrap();
        });
        let snap = counters.snapshot();
        assert_eq!(snap.total, 8 * 110);
        assert_eq!(snap.cross_numa, 8 * 100);
        assert_eq!(snap.messages, 16);
    }

    #[test]
    fn messages_from_same_peer_arrive_in_order() {
        let topo = Topology::new(presets::h800(), 2);
        let (results, _) = run_ranks(&topo, |h| {
            if h.rank == 0 {
                for i in 0..100u8 {
                    h.send(1, vec![i]).unwrap();
                }
                Vec::new()
            } else {
                (0..100).map(|_| h.recv(0).unwrap()[0]).collect::<Vec<u8>>()
            }
        });
        assert_eq!(results[1], (0..100).collect::<Vec<u8>>());
    }

    #[test]
    fn snapshot_and_reset_between_runs() {
        let topo = Topology::new(presets::h800(), 2);
        let (_, counters) = run_ranks(&topo, |h| {
            if h.rank == 0 {
                h.send(1, vec![0u8; 64]).unwrap();
            } else {
                let _ = h.recv(0).unwrap();
            }
        });
        // At rest, snapshot is coherent and reset clears everything.
        let snap = counters.snapshot();
        assert_eq!(snap, CountersSnapshot { total: 64, cross_numa: 0, messages: 1 });
        counters.reset();
        assert_eq!(counters.snapshot(), CountersSnapshot::default());
    }

    #[test]
    fn transport_stats_include_frame_overhead() {
        use crate::transport::FRAME_HEADER_LEN;
        let topo = Topology::new(presets::h800(), 2);
        let (stats, counters) = run_ranks(&topo, |h| {
            if h.rank == 0 {
                h.send(1, vec![0u8; 100]).unwrap();
            } else {
                let _ = h.recv(0).unwrap();
            }
            h.transport().stats()
        });
        // InProc stats are mesh-shared; payload accounting matches the
        // comm-layer counters, wire accounting adds one frame header. (The
        // send-side counters are deterministic here — every send
        // happens-before both snapshots; the buffered gauge is not, since
        // rank 0 may snapshot while rank 1's recv is still pending.)
        for s in &stats {
            assert_eq!(s.payload_bytes, counters.total_bytes());
            assert_eq!(s.wire_bytes, counters.total_bytes() + FRAME_HEADER_LEN as u64);
            assert_eq!(s.messages, 1);
        }
    }
}
