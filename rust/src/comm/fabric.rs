//! In-process communication fabric.
//!
//! Stands in for the GPU interconnect: N ranks run as threads, exchanging
//! byte payloads over per-pair channels. The collectives built on top move
//! *real encoded bytes* through it — quantize → bit-split pack → transfer →
//! unpack → dequantize → reduce — so functional behaviour (numerics, wire
//! format, QDQ placement) is exactly the paper's; only the physical
//! transport differs (see DESIGN.md §2). Per-link-class byte counters let
//! tests verify the Table 5 volume accounting against the closed forms.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

use crate::topo::Topology;

/// Byte counters, split by link class (Table 5 columns).
#[derive(Debug, Default)]
pub struct ByteCounters {
    /// All bytes that crossed any link.
    pub total: AtomicU64,
    /// Bytes that crossed the NUMA bridge (src and dst in different groups).
    pub cross_numa: AtomicU64,
    /// Number of point-to-point messages.
    pub messages: AtomicU64,
}

impl ByteCounters {
    pub fn total_bytes(&self) -> u64 {
        self.total.load(Ordering::Relaxed)
    }

    pub fn cross_numa_bytes(&self) -> u64 {
        self.cross_numa.load(Ordering::Relaxed)
    }

    pub fn message_count(&self) -> u64 {
        self.messages.load(Ordering::Relaxed)
    }

    pub fn reset(&self) {
        self.total.store(0, Ordering::Relaxed);
        self.cross_numa.store(0, Ordering::Relaxed);
        self.messages.store(0, Ordering::Relaxed);
    }
}

/// One rank's endpoint into the fabric.
pub struct RankHandle {
    pub rank: usize,
    pub n: usize,
    topo: Topology,
    tx: Vec<Sender<Vec<u8>>>,
    rx: Vec<Receiver<Vec<u8>>>,
    counters: Arc<ByteCounters>,
}

impl RankHandle {
    /// Send a payload to `dst` (non-blocking; channels are unbounded).
    pub fn send(&self, dst: usize, bytes: Vec<u8>) {
        assert_ne!(dst, self.rank, "self-send is a local copy, not a transfer");
        self.counters.total.fetch_add(bytes.len() as u64, Ordering::Relaxed);
        self.counters.messages.fetch_add(1, Ordering::Relaxed);
        if self.topo.numa_groups > 1 && self.topo.group_of(self.rank) != self.topo.group_of(dst) {
            self.counters.cross_numa.fetch_add(bytes.len() as u64, Ordering::Relaxed);
        }
        self.tx[dst].send(bytes).expect("peer hung up");
    }

    /// Block until a payload from `src` arrives.
    pub fn recv(&self, src: usize) -> Vec<u8> {
        assert_ne!(src, self.rank);
        self.rx[src].recv().expect("peer hung up")
    }

    /// The node topology this fabric models.
    pub fn topo(&self) -> &Topology {
        &self.topo
    }

    /// Shared byte counters (same instance across all ranks).
    pub fn counters(&self) -> &ByteCounters {
        &self.counters
    }
}

/// Build a fabric over `topo` and run `f` once per rank, each on its own
/// thread. Returns the per-rank results in rank order, plus the counters.
pub fn run_ranks<R, F>(topo: &Topology, f: F) -> (Vec<R>, Arc<ByteCounters>)
where
    R: Send,
    F: Fn(RankHandle) -> R + Sync,
{
    let n = topo.n_gpus;
    let counters = Arc::new(ByteCounters::default());
    // chan[s][d]: sender for s->d kept by s; receiver kept by d.
    let mut senders: Vec<Vec<Option<Sender<Vec<u8>>>>> = (0..n).map(|_| Vec::new()).collect();
    let mut receivers: Vec<Vec<Option<Receiver<Vec<u8>>>>> =
        (0..n).map(|_| (0..n).map(|_| None).collect()).collect();
    for s in 0..n {
        for d in 0..n {
            let (tx, rx) = channel();
            senders[s].push(Some(tx));
            receivers[d][s] = Some(rx);
        }
    }
    let mut handles = Vec::with_capacity(n);
    for (rank, rxs) in receivers.into_iter().enumerate() {
        let tx: Vec<Sender<Vec<u8>>> =
            (0..n).map(|d| senders[rank][d].take().unwrap()).collect();
        let rx: Vec<Receiver<Vec<u8>>> = rxs
            .into_iter()
            .enumerate()
            .map(|(s, r)| r.unwrap_or_else(|| panic!("missing channel {s}->{rank}")))
            .collect();
        handles.push(RankHandle {
            rank,
            n,
            topo: topo.clone(),
            tx,
            rx,
            counters: counters.clone(),
        });
    }
    let results = std::thread::scope(|scope| {
        let mut joins = Vec::with_capacity(n);
        for h in handles {
            let f = &f;
            joins.push(scope.spawn(move || f(h)));
        }
        joins.into_iter().map(|j| j.join().expect("rank panicked")).collect::<Vec<R>>()
    });
    (results, counters)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topo::{presets, Topology};

    fn l40x8() -> Topology {
        Topology::new(presets::l40(), 8)
    }

    #[test]
    fn pairwise_exchange_delivers() {
        let topo = Topology::new(presets::h800(), 4);
        let (results, _) = run_ranks(&topo, |h| {
            // Everyone sends its rank byte to everyone.
            for d in 0..h.n {
                if d != h.rank {
                    h.send(d, vec![h.rank as u8]);
                }
            }
            let mut got = Vec::new();
            for s in 0..h.n {
                if s != h.rank {
                    got.push(h.recv(s)[0]);
                }
            }
            got
        });
        assert_eq!(results[0], vec![1, 2, 3]);
        assert_eq!(results[3], vec![0, 1, 2]);
    }

    #[test]
    fn counters_track_total_and_cross_numa() {
        let topo = l40x8();
        let (_, counters) = run_ranks(&topo, |h| {
            // One 100-byte message to the bridge peer (cross) and one to an
            // intra-group neighbour.
            let peer = h.topo().bridge_peer(h.rank);
            h.send(peer, vec![0u8; 100]);
            let _ = h.recv(peer);
            let g = h.topo().group_members(h.rank);
            let neighbour = if h.rank + 1 < g.end { h.rank + 1 } else { g.start };
            h.send(neighbour, vec![0u8; 10]);
            let _ = h.recv(if h.rank > g.start { h.rank - 1 } else { g.end - 1 });
        });
        assert_eq!(counters.total_bytes(), 8 * 110);
        assert_eq!(counters.cross_numa_bytes(), 8 * 100);
        assert_eq!(counters.message_count(), 16);
    }

    #[test]
    fn messages_from_same_peer_arrive_in_order() {
        let topo = Topology::new(presets::h800(), 2);
        let (results, _) = run_ranks(&topo, |h| {
            if h.rank == 0 {
                for i in 0..100u8 {
                    h.send(1, vec![i]);
                }
                Vec::new()
            } else {
                (0..100).map(|_| h.recv(0)[0]).collect::<Vec<u8>>()
            }
        });
        assert_eq!(results[1], (0..100).collect::<Vec<u8>>());
    }
}
