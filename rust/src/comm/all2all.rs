//! All2All with quantized dispatch (expert parallelism, Table 10).
//!
//! Following DeepSeek-V3 (and the paper), only the *dispatch* direction —
//! tokens travelling to their experts — is quantized; the *combine*
//! direction (expert outputs coming back) stays BF16. Each rank provides
//! one payload per destination; the primitive returns one decoded payload
//! per source.
//!
//! Payload lengths are exchanged in-band (the wire header carries `n`), so
//! the decode path *validates the header against the delivered frame
//! before allocating*: a corrupted header claiming 4 billion elements is a
//! clean `CommError::Header`, not a multi-gigabyte allocation.

use super::{communicator::Communicator, encode, error::CommError};
use crate::quant::scheme::codec_from_header;
use crate::quant::wire::Header;
use crate::quant::{Codec, CodecBuffers};
use crate::record;
use crate::telemetry::{codec_tag, Op, Stage};
use crate::transport::Transport;

/// Exchange `sends[d]` with every rank `d`, quantizing with `codec`.
///
/// Returns `recv[s]` = the decoded payload rank `s` sent us. The self
/// payload (`sends[rank]`) takes the same QDQ so expert computation sees
/// wire precision regardless of token placement.
pub(crate) fn all2all<T: Transport>(
    c: &mut Communicator<T>,
    sends: &[Vec<f32>],
    codec: &Codec,
) -> Result<Vec<Vec<f32>>, CommError> {
    let Communicator { handle: h, bufs, codec_threads, .. } = c;
    let t = *codec_threads;
    if sends.len() != h.n {
        return Err(CommError::shape(format!(
            "{} payloads for a {}-rank all2all (one per destination)",
            sends.len(),
            h.n
        )));
    }
    if let Some(rec) = h.recorder() {
        rec.set_stage(Stage::Single, codec_tag(codec));
    }
    for (dst, payload) in sends.iter().enumerate() {
        if dst != h.rank {
            record!(h.recorder(), start Op::Encode, payload.len() as u64);
            let wire = encode(codec, payload, bufs, t)?;
            record!(h.recorder(), end Op::Encode, wire.len() as u64);
            h.send(dst, wire)?;
        }
    }
    let mut out = Vec::with_capacity(h.n);
    for src in 0..h.n {
        let wire = if src == h.rank {
            record!(h.recorder(), start Op::Encode, sends[src].len() as u64);
            let wire = encode(codec, &sends[src], bufs, t)?;
            record!(h.recorder(), end Op::Encode, wire.len() as u64);
            wire
        } else {
            h.recv(src)?
        };
        if h.recorder().is_some() {
            let elems = Header::parse(&wire).map(|hd| u64::from(hd.n)).unwrap_or(0);
            record!(h.recorder(), start Op::Decode, elems);
        }
        let decoded = decode_validated(src, &wire, bufs, t)?;
        record!(h.recorder(), end Op::Decode, wire.len() as u64);
        out.push(decoded);
    }
    Ok(out)
}

/// Decode one self-describing payload, validating the header's element
/// count against the frame's actual length *before* sizing the output —
/// the guard that turns a corrupted length field into a clean error
/// instead of an unbounded `vec![0f32; n]`.
fn decode_validated(
    src: usize,
    wire: &[u8],
    bufs: &mut CodecBuffers,
    threads: usize,
) -> Result<Vec<f32>, CommError> {
    let header = Header::parse(wire).map_err(|e| CommError::decode(src, e))?;
    let n = header.n as usize;
    let claimed = codec_from_header(&header).map_err(|e| CommError::decode(src, e))?;
    let expect = claimed.wire_len(n);
    if expect != wire.len() {
        return Err(CommError::header(
            src,
            format!(
                "header claims {n} elements ({expect} wire bytes) but the frame carries {} bytes",
                wire.len()
            ),
        ));
    }
    let mut buf = vec![0f32; n];
    Codec::decode_with_threads(wire, bufs, &mut buf, threads)
        .map_err(|e| CommError::decode(src, e))?;
    Ok(buf)
}

/// Dispatch (quantized) + combine (BF16) round trip: scatter token slices
/// to experts, get them back. Returns what each rank's tokens look like
/// after the full EP round trip with identity experts — used by tests to
/// isolate pure communication error.
#[cfg(test)]
pub(crate) fn dispatch_combine_identity<T: Transport>(
    c: &mut Communicator<T>,
    sends: &[Vec<f32>],
    dispatch_codec: &Codec,
) -> Result<Vec<Vec<f32>>, CommError> {
    let received = all2all(c, sends, dispatch_codec)?;
    // Identity "expert": send straight back, combine in BF16.
    all2all(c, &received, &Codec::Bf16)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::fabric::run_ranks;
    use crate::quant::Codec;
    use crate::topo::{presets, Topology};
    use crate::util::stats::sqnr_db;
    use crate::util::Prng;

    fn payloads(rank: usize, n: usize, len: usize) -> Vec<Vec<f32>> {
        let mut rng = Prng::new(7000 + rank as u64);
        (0..n)
            .map(|_| {
                let mut v = vec![0f32; len];
                rng.fill_activations(&mut v, 1.0);
                v
            })
            .collect()
    }

    #[test]
    fn bf16_all2all_routes_correctly() {
        let topo = Topology::new(presets::h800(), 4);
        let (results, _) = run_ranks(&topo, |h| {
            let mut c = Communicator::from_handle(h);
            let sends = payloads(c.rank(), c.n(), 64);
            let got = all2all(&mut c, &sends, &Codec::Bf16).unwrap();
            (sends, got)
        });
        for (dst, (_, got)) in results.iter().enumerate() {
            for (src, (sent, _)) in results.iter().enumerate() {
                let expect = &sent[dst];
                let actual = &got[src];
                assert_eq!(actual.len(), expect.len());
                for (a, e) in actual.iter().zip(expect) {
                    assert!((a - e).abs() <= e.abs() / 256.0 + 1e-6, "{src}->{dst}");
                }
            }
        }
    }

    #[test]
    fn ragged_payloads_supported() {
        // MoE routing is never balanced: different sizes per destination.
        let topo = Topology::new(presets::h800(), 4);
        let (results, _) = run_ranks(&topo, |h| {
            let mut c = Communicator::from_handle(h);
            let sends: Vec<Vec<f32>> =
                (0..c.n()).map(|d| vec![c.rank() as f32; (c.rank() + 1) * (d + 1)]).collect();
            all2all(&mut c, &sends, &Codec::parse("int8").unwrap()).unwrap()
        });
        for (dst, got) in results.iter().enumerate() {
            for (src, payload) in got.iter().enumerate() {
                assert_eq!(payload.len(), (src + 1) * (dst + 1), "{src}->{dst} length");
            }
        }
    }

    #[test]
    fn quantized_dispatch_quality_ordering() {
        let topo = Topology::new(presets::h800(), 8);
        let mut prev = f64::INFINITY;
        for spec in ["int8", "int5", "int3@32", "int2@32"] {
            let codec = Codec::parse(spec).unwrap();
            let (results, _) = run_ranks(&topo, |h| {
                let mut c = Communicator::from_handle(h);
                let sends = payloads(c.rank(), c.n(), 2048);
                let got = dispatch_combine_identity(&mut c, &sends, &codec).unwrap();
                (sends, got)
            });
            // Round-trip error on rank 0's own tokens.
            let (sent, got) = &results[0];
            let flat_s: Vec<f32> = sent.iter().flatten().cloned().collect();
            let flat_g: Vec<f32> = got.iter().flatten().cloned().collect();
            let s = sqnr_db(&flat_s, &flat_g);
            assert!(s < prev, "{spec}: {s} dB should degrade monotonically");
            prev = s;
        }
    }

    #[test]
    fn sr_dispatch_beats_rtn_at_int2() {
        let topo = Topology::new(presets::h800(), 8);
        let q = |spec: &str| {
            let codec = Codec::parse(spec).unwrap();
            let (results, _) = run_ranks(&topo, |h| {
                let mut c = Communicator::from_handle(h);
                let sends = payloads(c.rank(), c.n(), 4096);
                let got = dispatch_combine_identity(&mut c, &sends, &codec).unwrap();
                (sends, got)
            });
            let (sent, got) = &results[0];
            let flat_s: Vec<f32> = sent.iter().flatten().cloned().collect();
            let flat_g: Vec<f32> = got.iter().flatten().cloned().collect();
            sqnr_db(&flat_s, &flat_g)
        };
        let rtn = q("int2@32");
        let sr = q("int2-sr@32");
        assert!(sr > rtn + 4.0, "SR {sr} dB vs RTN {rtn} dB");
    }

    #[test]
    fn dispatch_volume_scales_with_bits() {
        let topo = Topology::new(presets::h800(), 8);
        let vol = |spec: &str| {
            let codec = Codec::parse(spec).unwrap();
            let (_, counters) = run_ranks(&topo, |h| {
                let mut c = Communicator::from_handle(h);
                let sends = payloads(c.rank(), c.n(), 1024);
                all2all(&mut c, &sends, &codec).unwrap();
            });
            counters.total_bytes() as f64
        };
        let bf = vol("bf16");
        let i4 = vol("int4@32");
        assert!((0.25..0.40).contains(&(i4 / bf)), "int4/bf16 wire ratio {}", i4 / bf);
    }

    #[test]
    fn wrong_payload_count_is_a_shape_error() {
        let topo = Topology::new(presets::h800(), 4);
        let (errs, _) = run_ranks(&topo, |h| {
            let mut c = Communicator::from_handle(h);
            let sends = payloads(c.rank(), 3, 8); // 3 payloads for 4 ranks
            all2all(&mut c, &sends, &Codec::Bf16).unwrap_err().to_string()
        });
        assert!(errs[0].contains("payloads"), "{}", errs[0]);
    }

    #[test]
    fn inflated_header_count_is_rejected_before_allocation() {
        // A corrupted wire header claiming u32::MAX elements must be caught
        // by the frame-length cross-check, not drive a 16 GB allocation.
        let codec = Codec::parse("int8").unwrap();
        let mut wire = codec.encode(&vec![1.0f32; 256]);
        // n lives at header bytes 8..12 (little-endian).
        wire[8..12].copy_from_slice(&u32::MAX.to_le_bytes());
        let mut bufs = CodecBuffers::default();
        let err = decode_validated(3, &wire, &mut bufs, 1).unwrap_err();
        match &err {
            CommError::Header { peer, detail } => {
                assert_eq!(*peer, 3);
                assert!(detail.contains("4294967295"), "{detail}");
            }
            other => panic!("expected Header error, got {other}"),
        }

        // A *shrunken* count is equally inconsistent with the frame.
        let mut wire = codec.encode(&vec![1.0f32; 256]);
        wire[8..12].copy_from_slice(&8u32.to_le_bytes());
        assert!(matches!(
            decode_validated(0, &wire, &mut bufs, 1).unwrap_err(),
            CommError::Header { .. }
        ));

        // An intact payload still decodes.
        let wire = codec.encode(&vec![1.0f32; 256]);
        let out = decode_validated(0, &wire, &mut bufs, 1).unwrap();
        assert_eq!(out.len(), 256);
    }
}
