//! All2All with quantized dispatch (expert parallelism, Table 10).
//!
//! Following DeepSeek-V3 (and the paper), only the *dispatch* direction —
//! tokens travelling to their experts — is quantized; the *combine*
//! direction (expert outputs coming back) stays BF16. Each rank provides
//! one payload per destination; the primitive returns one decoded payload
//! per source.

use super::encode;
use crate::comm::fabric::RankHandle;
use crate::quant::{Codec, CodecBuffers};
use crate::transport::Transport;

/// Exchange `sends[d]` with every rank `d`, quantizing with `codec`.
///
/// Returns `recv[s]` = the decoded payload rank `s` sent us. The self
/// payload (`sends[rank]`) takes the same QDQ so expert computation sees
/// wire precision regardless of token placement.
pub fn all2all<T: Transport>(h: &RankHandle<T>, sends: &[Vec<f32>], codec: &Codec) -> Vec<Vec<f32>> {
    assert_eq!(sends.len(), h.n, "one payload per destination rank");
    let mut bufs = CodecBuffers::default();
    // Lengths are exchanged in-band: the wire header carries n.
    for (dst, payload) in sends.iter().enumerate() {
        if dst != h.rank {
            h.send(dst, encode(codec, payload, &mut bufs));
        }
    }
    let mut out = Vec::with_capacity(h.n);
    for src in 0..h.n {
        let wire = if src == h.rank {
            encode(codec, &sends[src], &mut bufs)
        } else {
            h.recv(src)
        };
        let n = crate::quant::wire::Header::parse(&wire).expect("a2a header").n as usize;
        let mut buf = vec![0f32; n];
        Codec::decode_with(&wire, &mut bufs, &mut buf).expect("a2a decode");
        out.push(buf);
    }
    out
}

/// Dispatch (quantized) + combine (BF16) round trip: scatter token slices
/// to experts, get them back. Returns what each rank's tokens look like
/// after the full EP round trip with identity experts — used by tests to
/// isolate pure communication error.
pub fn dispatch_combine_identity<T: Transport>(
    h: &RankHandle<T>,
    sends: &[Vec<f32>],
    dispatch_codec: &Codec,
) -> Vec<Vec<f32>> {
    let received = all2all(h, sends, dispatch_codec);
    // Identity "expert": send straight back, combine in BF16.
    all2all(h, &received, &Codec::Bf16)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::fabric::run_ranks;
    use crate::quant::Codec;
    use crate::topo::{presets, Topology};
    use crate::util::stats::sqnr_db;
    use crate::util::Prng;

    fn payloads(rank: usize, n: usize, len: usize) -> Vec<Vec<f32>> {
        let mut rng = Prng::new(7000 + rank as u64);
        (0..n)
            .map(|_| {
                let mut v = vec![0f32; len];
                rng.fill_activations(&mut v, 1.0);
                v
            })
            .collect()
    }

    #[test]
    fn bf16_all2all_routes_correctly() {
        let topo = Topology::new(presets::h800(), 4);
        let (results, _) = run_ranks(&topo, |h| {
            let sends = payloads(h.rank, h.n, 64);
            (sends.clone(), all2all(&h, &sends, &Codec::Bf16))
        });
        for (dst, (_, got)) in results.iter().enumerate() {
            for (src, (sent, _)) in results.iter().enumerate() {
                let expect = &sent[dst];
                let actual = &got[src];
                assert_eq!(actual.len(), expect.len());
                for (a, e) in actual.iter().zip(expect) {
                    assert!((a - e).abs() <= e.abs() / 256.0 + 1e-6, "{src}->{dst}");
                }
            }
        }
    }

    #[test]
    fn ragged_payloads_supported() {
        // MoE routing is never balanced: different sizes per destination.
        let topo = Topology::new(presets::h800(), 4);
        let (results, _) = run_ranks(&topo, |h| {
            let sends: Vec<Vec<f32>> =
                (0..h.n).map(|d| vec![h.rank as f32; (h.rank + 1) * (d + 1)]).collect();
            all2all(&h, &sends, &Codec::parse("int8").unwrap())
        });
        for (dst, got) in results.iter().enumerate() {
            for (src, payload) in got.iter().enumerate() {
                assert_eq!(payload.len(), (src + 1) * (dst + 1), "{src}->{dst} length");
            }
        }
    }

    #[test]
    fn quantized_dispatch_quality_ordering() {
        let topo = Topology::new(presets::h800(), 8);
        let mut prev = f64::INFINITY;
        for spec in ["int8", "int5", "int3@32", "int2@32"] {
            let codec = Codec::parse(spec).unwrap();
            let (results, _) = run_ranks(&topo, |h| {
                let sends = payloads(h.rank, h.n, 2048);
                (sends.clone(), dispatch_combine_identity(&h, &sends, &codec))
            });
            // Round-trip error on rank 0's own tokens.
            let (sent, got) = &results[0];
            let flat_s: Vec<f32> = sent.iter().flatten().cloned().collect();
            let flat_g: Vec<f32> = got.iter().flatten().cloned().collect();
            let s = sqnr_db(&flat_s, &flat_g);
            assert!(s < prev, "{spec}: {s} dB should degrade monotonically");
            prev = s;
        }
    }

    #[test]
    fn sr_dispatch_beats_rtn_at_int2() {
        let topo = Topology::new(presets::h800(), 8);
        let q = |spec: &str| {
            let codec = Codec::parse(spec).unwrap();
            let (results, _) = run_ranks(&topo, |h| {
                let sends = payloads(h.rank, h.n, 4096);
                (sends.clone(), dispatch_combine_identity(&h, &sends, &codec))
            });
            let (sent, got) = &results[0];
            let flat_s: Vec<f32> = sent.iter().flatten().cloned().collect();
            let flat_g: Vec<f32> = got.iter().flatten().cloned().collect();
            sqnr_db(&flat_s, &flat_g)
        };
        let rtn = q("int2@32");
        let sr = q("int2-sr@32");
        assert!(sr > rtn + 4.0, "SR {sr} dB vs RTN {rtn} dB");
    }

    #[test]
    fn dispatch_volume_scales_with_bits() {
        let topo = Topology::new(presets::h800(), 8);
        let vol = |spec: &str| {
            let codec = Codec::parse(spec).unwrap();
            let (_, counters) = run_ranks(&topo, |h| {
                let sends = payloads(h.rank, h.n, 1024);
                all2all(&h, &sends, &codec);
            });
            counters.total_bytes() as f64
        };
        let bf = vol("bf16");
        let i4 = vol("int4@32");
        assert!((0.25..0.40).contains(&(i4 / bf)), "int4/bf16 wire ratio {}", i4 / bf);
    }
}
