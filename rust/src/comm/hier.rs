//! Hierarchical two-step AllReduce over G link-tier groups (Figs. 6–7,
//! generalized).
//!
//! Three stages, each quantized with the fused codec:
//!
//! 1. **Partial reduce-scatter inside each group** — rank `g·s + j`
//!    collects and reduces chunk `j` from its group peers over the fast
//!    intra-group fabric.
//! 2. **Cross-group reduction** — the G ranks holding chunk `j`'s partials
//!    (the *column* `{g·s + j | g in 0..G}`, one leader per group) ring
//!    all-gather their **encoded** partials: each member encodes once and
//!    the G wire images circulate verbatim over the G−1 hops, so there is
//!    no re-quantization along the ring. Every member then decodes all G
//!    images *in group order* and sums — the same bits on every side.
//!    Only M/s per rank crosses the inter-group link per hop — the 3×
//!    cross-NUMA saving of Table 5 at G = 2.
//! 3. **Partial all-gather inside each group** — the reduced chunks
//!    circulate over the intra-group fabric again.
//!
//! At `G = 2` the column ring degenerates *bit-identically* to the
//! original symmetric bridge-pair exchange (next == prev == `bridge_peer`,
//! one send each way, decode in group order) — pinned against the
//! pre-refactor pairwise implementation, wire bytes included, in the tests
//! below. All ranks of all groups end bit-identical because every column
//! decodes the same images in the same order and re-encodes the identical
//! sum for stage 3.
//!
//! Admissibility ([`Algo::admissible`]): `G >= 2` groups joined by an
//! inter-group link. A flat topology is a `CommError::Topology`, not a
//! panic — `AlgoPolicy::Auto` never routes here on flat nodes.

use super::{chunk_range, communicator::Communicator, encode, error::CommError, Algo};
use crate::comm::fabric::RankHandle;
use crate::plan::StageCodecs;
use crate::quant::{Codec, CodecBuffers};
use crate::record;
use crate::telemetry::{codec_tag, Op, Stage};
use crate::topo::Topology;
use crate::transport::Transport;

/// Stage 2 — the cross-group column ring, shared by [`allreduce`] and the
/// pipelined variant ([`super::pipeline`]): `acc` (this rank's reduced
/// partial) is encoded exactly once; the G column members' wire images
/// circulate verbatim over G−1 hops; then `acc` is rebuilt as the
/// group-ordered decode-sum of all G images, so every column member lands
/// on identical bits. One copy of the hop arithmetic and the
/// bit-identity-critical decode order — the G=2 wire-hash golden test
/// below pins it for both callers.
pub(crate) fn cross_group_reduce<T: Transport>(
    h: &RankHandle<T>,
    bufs: &mut CodecBuffers,
    acc: &mut Vec<f32>,
    codec: &Codec,
    threads: usize,
    topo: &Topology,
) -> Result<(), CommError> {
    let gcount = topo.numa_groups;
    let g = topo.group_of(h.rank);
    if let Some(rec) = h.recorder() {
        rec.set_stage(Stage::CrossGroup, codec_tag(codec));
    }
    record!(h.recorder(), start Op::Encode, acc.len() as u64);
    let wire_mine = encode(codec, acc, bufs, threads)?;
    record!(h.recorder(), end Op::Encode, wire_mine.len() as u64);
    let mut by_group: Vec<Vec<u8>> = vec![Vec::new(); gcount];
    by_group[g] = wire_mine;
    let next = topo.peer_in_group(h.rank, (g + 1) % gcount);
    let prev = topo.peer_in_group(h.rank, (g + gcount - 1) % gcount);
    for hop in 1..gcount {
        let fwd = (g + gcount + 1 - hop) % gcount; // hop 1 forwards our own
        let got = (g + gcount - hop) % gcount;
        h.send(next, by_group[fwd].clone())?;
        by_group[got] = h.recv(prev)?;
    }
    acc.iter_mut().for_each(|x| *x = 0.0);
    for (src_g, wire) in by_group.iter().enumerate() {
        // Blame decode failures on the payload's *origin* — group src_g's
        // column member (one of the images is this rank's own encoding).
        let src = topo.peer_in_group(h.rank, src_g);
        record!(h.recorder(), start Op::DecodeSum, acc.len() as u64);
        Codec::decode_sum_with_threads(wire, bufs, acc, threads)
            .map_err(|e| CommError::decode(src, e))?;
        record!(h.recorder(), end Op::DecodeSum, wire.len() as u64);
    }
    Ok(())
}

/// In-place hierarchical AllReduce with one codec per stage — the plan
/// execution path. Each stage re-encodes its freshly reduced f32
/// accumulator (the pre-existing QDQ boundaries), so a more aggressive
/// cross codec requantizes exactly where a uniform run would have
/// re-encoded anyway: the QDQ count stays 3 regardless of the mix, and
/// every rank stays bit-identical because all ranks run the same plan.
/// Requires `G >= 2` link-tier groups.
pub(crate) fn allreduce_staged<T: Transport>(
    c: &mut Communicator<T>,
    data: &mut [f32],
    stages: &StageCodecs,
) -> Result<(), CommError> {
    let Communicator { handle: h, bufs, acc, codec_threads, .. } = c;
    let t = *codec_threads;
    let topo = h.topo().clone();
    Algo::Hier.admissible(&topo)?;
    let s = topo.group_size();
    let group = topo.group_members(h.rank);
    let j = h.rank - group.start; // index within the group

    // Stage 1 — partial reduce-scatter within the group.
    if let Some(rec) = h.recorder() {
        rec.set_stage(Stage::ReduceScatter, codec_tag(&stages.intra_rs));
    }
    for peer_j in 0..s {
        let peer = group.start + peer_j;
        if peer != h.rank {
            let r = chunk_range(data.len(), s, peer_j);
            record!(h.recorder(), start Op::Encode, r.len() as u64);
            let wire = encode(&stages.intra_rs, &data[r], bufs, t)?;
            record!(h.recorder(), end Op::Encode, wire.len() as u64);
            h.send(peer, wire)?;
        }
    }
    let own = chunk_range(data.len(), s, j);
    acc.clear();
    acc.extend_from_slice(&data[own.clone()]);
    for peer_j in 0..s {
        let peer = group.start + peer_j;
        if peer != h.rank {
            let wire = h.recv(peer)?;
            record!(h.recorder(), start Op::DecodeSum, acc.len() as u64);
            Codec::decode_sum_with_threads(&wire, bufs, acc, t)
                .map_err(|e| CommError::decode(peer, e))?;
            record!(h.recorder(), end Op::DecodeSum, wire.len() as u64);
        }
    }

    // Stage 2 — cross-group reduction over this rank's column: ring
    // all-gather of the G encoded partials (forwarded verbatim — exactly
    // one QDQ per partial no matter how many hops), then a group-ordered
    // decode-sum so every column member lands on identical bits. This is
    // the slow-tier stage: its codec may be more aggressive than the
    // intra stages'.
    cross_group_reduce(h, bufs, acc, &stages.cross, t, &topo)?;

    // Stage 3 — partial all-gather within the group.
    if let Some(rec) = h.recorder() {
        rec.set_stage(Stage::AllGather, codec_tag(&stages.intra_ag));
    }
    record!(h.recorder(), start Op::Encode, acc.len() as u64);
    let wire = encode(&stages.intra_ag, acc, bufs, t)?;
    record!(h.recorder(), end Op::Encode, wire.len() as u64);
    for peer_j in 0..s {
        let p = group.start + peer_j;
        if p != h.rank {
            h.send(p, wire.clone())?;
        }
    }
    record!(h.recorder(), start Op::Decode, own.len() as u64);
    Codec::decode_with_threads(&wire, bufs, &mut data[own], t)
        .map_err(|e| CommError::decode(h.rank, e))?;
    record!(h.recorder(), end Op::Decode, wire.len() as u64);
    for peer_j in 0..s {
        let p = group.start + peer_j;
        if p != h.rank {
            let wire = h.recv(p)?;
            let r = chunk_range(data.len(), s, peer_j);
            record!(h.recorder(), start Op::Decode, r.len() as u64);
            Codec::decode_with_threads(&wire, bufs, &mut data[r], t)
                .map_err(|e| CommError::decode(p, e))?;
            record!(h.recorder(), end Op::Decode, wire.len() as u64);
        }
    }
    Ok(())
}

/// In-place hierarchical AllReduce with one codec everywhere — the
/// uniform special case of [`allreduce_staged`] (what the `AlgoPolicy`
/// shim and the pre-plan tests run).
pub(crate) fn allreduce<T: Transport>(
    c: &mut Communicator<T>,
    data: &mut [f32],
    codec: &Codec,
) -> Result<(), CommError> {
    allreduce_staged(c, data, &StageCodecs::uniform(*codec))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::fabric::{run_ranks, run_ranks_with, RankHandle};
    use crate::comm::testutil::harness;
    use crate::quant::Codec;
    use crate::topo::{presets, Topology};
    use crate::transport::{inproc, Transport, TransportStats};
    use crate::util::stats::sqnr_db;
    use std::collections::BTreeMap;
    use std::sync::{Arc, Mutex};

    #[test]
    fn matches_serial_sum() {
        let topo = Topology::new(presets::l40(), 8);
        for (spec, min_db) in [("bf16", 35.0), ("int8", 26.0), ("int5", 14.0), ("int2-sr@32", 5.0)]
        {
            let codec = Codec::parse(spec).unwrap();
            let (results, expected) = harness(&topo, 3000, &codec, allreduce);
            for r in &results {
                assert_eq!(r, &results[0], "{spec}: all 8 ranks (both groups) agree");
            }
            let s = sqnr_db(&expected, &results[0]);
            assert!(s > min_db, "{spec}: SQNR {s} dB");
        }
    }

    #[test]
    fn matches_serial_sum_on_generalized_groups() {
        // The tentpole: the same collective on G = 4 PCIe groups and on a
        // dual-NVLink-node cluster. All ranks bit-identical, quality within
        // the codec's band.
        for topo in [presets::four_group_pcie(8).unwrap(), presets::dual_nvlink_node(8).unwrap()]
        {
            for (spec, min_db) in [("bf16", 35.0), ("int8", 24.0), ("int2-sr@32!", 5.0)] {
                let codec = Codec::parse(spec).unwrap();
                let (results, expected) = harness(&topo, 3000, &codec, allreduce);
                for r in &results {
                    assert_eq!(
                        r,
                        &results[0],
                        "{spec} on {}x{}: ranks diverge",
                        topo.spec.name,
                        topo.numa_groups
                    );
                }
                let s = sqnr_db(&expected, &results[0]);
                assert!(s > min_db, "{spec} G={}: SQNR {s} dB", topo.numa_groups);
            }
        }
    }

    #[test]
    fn groups_of_one_degenerate_to_the_column_ring() {
        // G == n (group size 1): stage 1 and 3 are empty, the whole
        // collective is one ring all-gather of encoded full payloads.
        let topo = Topology::with_groups(presets::l40(), 4, 4);
        let codec = Codec::parse("int8").unwrap();
        let (results, expected) = harness(&topo, 777, &codec, allreduce);
        for r in &results {
            assert_eq!(r, &results[0]);
        }
        let s = sqnr_db(&expected, &results[0]);
        assert!(s > 24.0, "SQNR {s}");
    }

    #[test]
    fn mixed_stage_codecs_stay_bit_identical_and_cut_cross_bytes() {
        // The plan path: int4 intra stages, int2-sr cross ring. All ranks
        // must still agree bitwise (same plan everywhere ⇒ same images in
        // the same order), quality stays in the aggressive codec's band,
        // and the *measured* cross-group bytes shrink by the wire-ratio
        // quotient while intra traffic is untouched.
        let topo = Topology::new(presets::l40(), 8);
        let intra = Codec::parse("int4@32").unwrap();
        let cross = Codec::parse("int2-sr@32!").unwrap();
        let mixed = StageCodecs::with_cross(intra, cross);
        let (results, expected) =
            harness(&topo, 3000, &intra, |c, d, _| allreduce_staged(c, d, &mixed));
        for r in &results {
            assert_eq!(r, &results[0], "mixed-stage ranks diverge");
        }
        let s = sqnr_db(&expected, &results[0]);
        assert!(s > 5.0, "mixed-stage SQNR {s} dB");

        let len = 4096usize;
        let measure = |stages: StageCodecs| {
            let inputs: Vec<f32> = (0..len).map(|i| (i as f32).sin()).collect();
            let ir = &inputs;
            let (_, counters) = run_ranks(&topo, |h| {
                let mut c = Communicator::from_handle(h);
                let mut d = ir.clone();
                allreduce_staged(&mut c, &mut d, &stages).unwrap();
            });
            counters.snapshot()
        };
        let uni = measure(StageCodecs::uniform(intra));
        let mix = measure(mixed);
        let intra_uni = uni.total - uni.cross_numa;
        let intra_mix = mix.total - mix.cross_numa;
        assert_eq!(intra_uni, intra_mix, "intra stages keep the base codec's bytes");
        let want = cross.asymptotic_wire_ratio() / intra.asymptotic_wire_ratio();
        let got = mix.cross_numa as f64 / uni.cross_numa as f64;
        assert!(
            (got - want).abs() < 0.05,
            "cross bytes ratio {got} vs wire-ratio quotient {want}"
        );
    }

    #[test]
    fn agrees_with_twostep_quality() {
        // Hier has 3 QDQ rounds vs two-step's 2: a small, bounded quality
        // cost (the price of the 4x cross-NUMA volume saving).
        let topo = Topology::new(presets::l40(), 8);
        let codec = Codec::parse("int4@32").unwrap();
        let (hier_r, expected) = harness(&topo, 8192, &codec, allreduce);
        let (two_r, _) = harness(&topo, 8192, &codec, super::super::twostep::allreduce);
        let hier_s = sqnr_db(&expected, &hier_r[0]);
        let two_s = sqnr_db(&expected, &two_r[0]);
        assert!(hier_s > two_s - 4.5, "hier {hier_s} dB vs two-step {two_s} dB");
        assert!(hier_s < two_s + 1.0, "hier cannot beat two-step");
    }

    #[test]
    fn cross_numa_volume_is_2m_measured() {
        // The fabric measures the *physical* floor: M/s per rank in each
        // bridge direction = 2M total. Table 5's "M" counts the reduction
        // direction only (the paper's accounting) — see sim::volume.
        let topo = Topology::new(presets::l40(), 8);
        let len = 4096usize;
        let inputs: Vec<f32> = (0..len).map(|i| i as f32 * 0.5).collect();
        let ir = &inputs;
        let (_, counters) = run_ranks(&topo, |h| {
            let mut c = Communicator::from_handle(h);
            let mut data = ir.clone();
            allreduce(&mut c, &mut data, &Codec::Bf16).unwrap();
        });
        let m = 2.0 * len as f64;
        let cross = counters.cross_numa_bytes() as f64;
        assert!((cross / (2.0 * m) - 1.0).abs() < 0.05, "cross {cross} vs 2M {}", 2.0 * m);
        // 4x less than two-step's measured 8M (4M per direction).
        let total = counters.total_bytes() as f64;
        assert!((total / (14.0 * m) - 1.0).abs() < 0.05, "total {total}");
    }

    #[test]
    fn cross_group_volume_scales_with_g() {
        // Measured cross-group bytes = N·(G−1)·chunk = G·(G−1)·M total
        // (all ring hops, both directions counted by the fabric).
        let len = 4096usize;
        let measure = |topo: &Topology| {
            let inputs: Vec<f32> = (0..len).map(|i| i as f32).collect();
            let ir = &inputs;
            let (_, counters) = run_ranks(topo, |h| {
                let mut c = Communicator::from_handle(h);
                let mut data = ir.clone();
                allreduce(&mut c, &mut data, &Codec::Bf16).unwrap();
            });
            counters.cross_numa_bytes() as f64
        };
        let m = 2.0 * len as f64;
        let g2 = measure(&Topology::new(presets::l40(), 8));
        let g4 = measure(&presets::four_group_pcie(8).unwrap());
        assert!((g2 / (2.0 * m) - 1.0).abs() < 0.05, "G=2 cross {g2}");
        assert!((g4 / (12.0 * m) - 1.0).abs() < 0.05, "G=4 cross {g4} vs 12M");
    }

    #[test]
    fn cross_numa_far_below_twostep() {
        let topo = Topology::new(presets::l40(), 8);
        let len = 4096usize;
        type AlgoFn = fn(
            &mut Communicator,
            &mut [f32],
            &Codec,
        ) -> Result<(), CommError>;
        let run = |f: AlgoFn| {
            let inputs: Vec<f32> = (0..len).map(|i| i as f32).collect();
            let ir = &inputs;
            let (_, c) = run_ranks(&topo, |h| {
                let mut comm = Communicator::from_handle(h);
                let mut data = ir.clone();
                f(&mut comm, &mut data, &Codec::Bf16).unwrap();
            });
            c.cross_numa_bytes() as f64
        };
        let two = run(super::super::twostep::allreduce);
        let hier = run(allreduce);
        // Table 5: 4M vs M per direction — a 4x saving either way you count.
        assert!((two / hier - 4.0).abs() < 0.2, "two-step {two} vs hier {hier}");
    }

    #[test]
    fn works_on_4_gpus() {
        let topo = Topology::new(presets::l40(), 4); // 2 groups of 2
        let (results, expected) = harness(&topo, 513, &Codec::parse("int8").unwrap(), allreduce);
        let s = sqnr_db(&expected, &results[0]);
        assert!(s > 24.0, "SQNR {s}");
    }

    #[test]
    fn flat_topology_is_a_clean_error() {
        let topo = Topology::new(presets::h800(), 4);
        let (errs, _) = run_ranks(&topo, |h| {
            let mut c = Communicator::from_handle(h);
            let mut data = vec![1.0f32; 64];
            allreduce(&mut c, &mut data, &Codec::Bf16).unwrap_err().to_string()
        });
        assert!(errs[0].contains("NUMA"), "{}", errs[0]);
    }

    // --- G = 2 bit-identity against the pre-refactor pairwise exchange ---

    /// The pre-refactor stage-2: symmetric `bridge_peer` pair exchange,
    /// kept verbatim (modulo the fallible encode helper) as the golden
    /// reference the generalized column ring must match wire-for-wire.
    fn allreduce_pairwise_reference<T: Transport>(
        c: &mut Communicator<T>,
        data: &mut [f32],
        codec: &Codec,
    ) -> Result<(), CommError> {
        let Communicator { handle: h, bufs, acc, codec_threads, .. } = c;
        let t = *codec_threads;
        let topo = h.topo().clone();
        assert_eq!(topo.numa_groups, 2, "the pairwise reference is the G=2 special case");
        let s = topo.group_size();
        let group = topo.group_members(h.rank);
        let j = h.rank - group.start;

        for peer_j in 0..s {
            let peer = group.start + peer_j;
            if peer != h.rank {
                let r = chunk_range(data.len(), s, peer_j);
                h.send(peer, encode(codec, &data[r], bufs, t)?)?;
            }
        }
        let own = chunk_range(data.len(), s, j);
        acc.clear();
        acc.extend_from_slice(&data[own.clone()]);
        for peer_j in 0..s {
            let peer = group.start + peer_j;
            if peer != h.rank {
                let wire = h.recv(peer)?;
                Codec::decode_sum_with_threads(&wire, bufs, acc, t)
                    .map_err(|e| CommError::decode(peer, e))?;
            }
        }

        let peer = topo.bridge_peer(h.rank);
        let wire_mine = encode(codec, acc, bufs, t)?;
        h.send(peer, wire_mine.clone())?;
        let wire_peer = h.recv(peer)?;
        let (first, f_src, second, s_src) = if h.rank < peer {
            (&wire_mine, h.rank, &wire_peer, peer)
        } else {
            (&wire_peer, peer, &wire_mine, h.rank)
        };
        acc.iter_mut().for_each(|x| *x = 0.0);
        Codec::decode_sum_with_threads(first, bufs, acc, t)
            .map_err(|e| CommError::decode(f_src, e))?;
        Codec::decode_sum_with_threads(second, bufs, acc, t)
            .map_err(|e| CommError::decode(s_src, e))?;

        let wire = encode(codec, acc, bufs, t)?;
        for peer_j in 0..s {
            let p = group.start + peer_j;
            if p != h.rank {
                h.send(p, wire.clone())?;
            }
        }
        Codec::decode_with_threads(&wire, bufs, &mut data[own], t)
            .map_err(|e| CommError::decode(h.rank, e))?;
        for peer_j in 0..s {
            let p = group.start + peer_j;
            if p != h.rank {
                let wire = h.recv(p)?;
                let r = chunk_range(data.len(), s, peer_j);
                Codec::decode_with_threads(&wire, bufs, &mut data[r], t)
                    .map_err(|e| CommError::decode(p, e))?;
            }
        }
        Ok(())
    }

    /// Per-link FNV-1a hashes + message/byte counts of every payload a
    /// collective puts on the wire, in send order.
    type WireLog = Arc<Mutex<BTreeMap<(usize, usize), (u64, u64, u64)>>>;

    struct HashingTransport<T: Transport> {
        inner: T,
        log: WireLog,
    }

    impl<T: Transport> Transport for HashingTransport<T> {
        fn rank(&self) -> usize {
            self.inner.rank()
        }
        fn n(&self) -> usize {
            self.inner.n()
        }
        fn send(&self, dst: usize, payload: Vec<u8>) -> anyhow::Result<()> {
            let mut log = self.log.lock().unwrap();
            let entry = log.entry((self.inner.rank(), dst)).or_insert((0xcbf29ce484222325, 0, 0));
            for &b in &payload {
                entry.0 ^= b as u64;
                entry.0 = entry.0.wrapping_mul(0x100000001b3);
            }
            entry.1 += 1;
            entry.2 += payload.len() as u64;
            drop(log);
            self.inner.send(dst, payload)
        }
        fn recv(&self, src: usize) -> anyhow::Result<Vec<u8>> {
            self.inner.recv(src)
        }
        fn stats(&self) -> TransportStats {
            self.inner.stats()
        }
    }

    fn hashed_mesh(n: usize) -> (Vec<HashingTransport<inproc::InProcTransport>>, WireLog) {
        let log: WireLog = Arc::new(Mutex::new(BTreeMap::new()));
        let endpoints = inproc::mesh(n)
            .into_iter()
            .map(|t| HashingTransport { inner: t, log: log.clone() })
            .collect();
        (endpoints, log)
    }

    #[test]
    fn recording_leaves_wire_bytes_bit_identical() {
        // Telemetry must be a pure observer: with the flight recorder
        // enabled, every link carries the exact same bytes in the exact
        // same order (golden per-link wire hashes) and every rank lands on
        // the exact same bits as the unrecorded run.
        let topo = Topology::new(presets::l40(), 8);
        let inputs: Vec<Vec<f32>> = (0..8)
            .map(|r| {
                let mut rng = crate::util::Prng::new(4200 + r as u64);
                let mut v = vec![0f32; 3000];
                rng.fill_activations(&mut v, 1.0);
                v
            })
            .collect();
        let codec = Codec::parse("int4@32").unwrap();
        let ir = &inputs;
        let run = |record: bool| {
            let (endpoints, log) = hashed_mesh(8);
            let (results, _) = run_ranks_with(endpoints, &topo, |h: RankHandle<_>| {
                let mut c = Communicator::from_handle(h);
                if record {
                    c.enable_recording(256);
                }
                let mut d = ir[c.rank()].clone();
                allreduce(&mut c, &mut d, &codec).unwrap();
                d
            });
            let log = Arc::try_unwrap(log).unwrap().into_inner().unwrap();
            (results, log)
        };
        let (off_r, off_log) = run(false);
        let (on_r, on_log) = run(true);
        assert_eq!(on_log, off_log, "recording must not change a single wire byte");
        for r in 0..8 {
            let a: Vec<u32> = on_r[r].iter().map(|x| x.to_bits()).collect();
            let b: Vec<u32> = off_r[r].iter().map(|x| x.to_bits()).collect();
            assert_eq!(a, b, "rank {r} numerics diverge under recording");
        }
    }

    #[test]
    fn generalized_g2_is_wire_identical_to_pairwise_exchange() {
        // The acceptance pin: at G = 2 the column ring must put the exact
        // same bytes on the exact same links in the exact same order as the
        // pre-refactor pairwise bridge exchange — golden per-link wire
        // hashes, not just equal results.
        let topo = Topology::new(presets::l40(), 8);
        let inputs: Vec<Vec<f32>> = (0..8)
            .map(|r| {
                let mut rng = crate::util::Prng::new(1000 + r as u64);
                let mut v = vec![0f32; 3000];
                rng.fill_activations(&mut v, 1.0);
                v
            })
            .collect();
        for spec in ["bf16", "int4@32", "int2-sr@32!"] {
            let codec = Codec::parse(spec).unwrap();
            let ir = &inputs;
            let run = |pairwise: bool| {
                let (endpoints, log) = hashed_mesh(8);
                let (results, _) = run_ranks_with(endpoints, &topo, |h: RankHandle<_>| {
                    let mut c = Communicator::from_handle(h);
                    let mut d = ir[c.rank()].clone();
                    if pairwise {
                        allreduce_pairwise_reference(&mut c, &mut d, &codec).unwrap();
                    } else {
                        allreduce(&mut c, &mut d, &codec).unwrap();
                    }
                    d
                });
                let log = Arc::try_unwrap(log).unwrap().into_inner().unwrap();
                (results, log)
            };
            let (new_r, new_log) = run(false);
            let (old_r, old_log) = run(true);
            for r in 0..8 {
                let a: Vec<u32> = new_r[r].iter().map(|x| x.to_bits()).collect();
                let b: Vec<u32> = old_r[r].iter().map(|x| x.to_bits()).collect();
                assert_eq!(a, b, "{spec}: rank {r} result diverges from pre-refactor path");
            }
            assert_eq!(
                new_log, old_log,
                "{spec}: per-link wire hashes diverge from the pre-refactor pair exchange"
            );
        }
    }
}
