//! Hierarchical two-step AllReduce for NUMA nodes (Figs. 6–7).
//!
//! Three stages, each quantized with the fused codec:
//!
//! 1. **Partial reduce-scatter inside each NUMA group** — rank `g·s + j`
//!    collects and reduces chunk `j` from its group peers over PCIe.
//! 2. **Cross-NUMA reduction** — each rank exchanges its partial chunk with
//!    its bridge peer (`rank ± s`) and reduces, so both sides hold the full
//!    sum of their chunk. Only M/s per rank crosses the bridge — the 3×
//!    cross-NUMA saving of Table 5.
//! 3. **Partial all-gather inside each NUMA group** — the reduced chunks
//!    circulate over PCIe again.
//!
//! Ranks in the two groups see identical results because the stage-2
//! exchange is symmetric and stage-3 redistributes the same payloads.
//! A topology without exactly two NUMA groups is a `CommError::Topology`,
//! not a panic — `AlgoPolicy::Auto` never routes here on flat nodes.

use super::{chunk_range, communicator::Communicator, encode, error::CommError, Algo};
use crate::quant::Codec;
use crate::transport::Transport;

/// In-place hierarchical AllReduce. Requires a 2-NUMA-group topology.
pub(crate) fn allreduce<T: Transport>(
    c: &mut Communicator<T>,
    data: &mut [f32],
    codec: &Codec,
) -> Result<(), CommError> {
    let Communicator { handle: h, bufs, acc, codec_threads, .. } = c;
    let t = *codec_threads;
    let topo = h.topo().clone();
    if topo.numa_groups != 2 {
        return Err(CommError::topology(
            Algo::Hier,
            format!("needs 2 NUMA groups, topology has {}", topo.numa_groups),
        ));
    }
    let s = topo.group_size();
    let group = topo.group_members(h.rank);
    let j = h.rank - group.start; // index within the group

    // Stage 1 — partial reduce-scatter within the NUMA group.
    for peer_j in 0..s {
        let peer = group.start + peer_j;
        if peer != h.rank {
            let r = chunk_range(data.len(), s, peer_j);
            h.send(peer, encode(codec, &data[r], bufs, t))?;
        }
    }
    let own = chunk_range(data.len(), s, j);
    acc.clear();
    acc.extend_from_slice(&data[own.clone()]);
    for peer_j in 0..s {
        let peer = group.start + peer_j;
        if peer != h.rank {
            let wire = h.recv(peer)?;
            Codec::decode_sum_with_threads(&wire, bufs, acc, t)
                .map_err(|e| CommError::decode(peer, e))?;
        }
    }

    // Stage 2 — cross-NUMA reduction with the bridge peer. Both sides sum
    // the *decoded* images of both partials in group order, so the two
    // groups end bit-identical despite the lossy wire.
    let peer = topo.bridge_peer(h.rank);
    let wire_mine = encode(codec, acc, bufs, t);
    h.send(peer, wire_mine.clone())?;
    let wire_peer = h.recv(peer)?;
    // Blame decode failures on the payload's actual source: one of the two
    // is this rank's own re-encoding, not the bridge peer's.
    let (first, f_src, second, s_src) = if h.rank < peer {
        (&wire_mine, h.rank, &wire_peer, peer)
    } else {
        (&wire_peer, peer, &wire_mine, h.rank)
    };
    acc.iter_mut().for_each(|x| *x = 0.0);
    Codec::decode_sum_with_threads(first, bufs, acc, t)
        .map_err(|e| CommError::decode(f_src, e))?;
    Codec::decode_sum_with_threads(second, bufs, acc, t)
        .map_err(|e| CommError::decode(s_src, e))?;

    // Stage 3 — partial all-gather within the NUMA group.
    let wire = encode(codec, acc, bufs, t);
    for peer_j in 0..s {
        let p = group.start + peer_j;
        if p != h.rank {
            h.send(p, wire.clone())?;
        }
    }
    Codec::decode_with_threads(&wire, bufs, &mut data[own], t)
        .map_err(|e| CommError::decode(h.rank, e))?;
    for peer_j in 0..s {
        let p = group.start + peer_j;
        if p != h.rank {
            let wire = h.recv(p)?;
            let r = chunk_range(data.len(), s, peer_j);
            Codec::decode_with_threads(&wire, bufs, &mut data[r], t)
                .map_err(|e| CommError::decode(p, e))?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::fabric::run_ranks;
    use crate::comm::testutil::harness;
    use crate::quant::Codec;
    use crate::topo::{presets, Topology};
    use crate::util::stats::sqnr_db;

    #[test]
    fn matches_serial_sum() {
        let topo = Topology::new(presets::l40(), 8);
        for (spec, min_db) in [("bf16", 35.0), ("int8", 26.0), ("int5", 14.0), ("int2-sr@32", 5.0)]
        {
            let codec = Codec::parse(spec).unwrap();
            let (results, expected) = harness(&topo, 3000, &codec, allreduce);
            for r in &results {
                assert_eq!(r, &results[0], "{spec}: all 8 ranks (both groups) agree");
            }
            let s = sqnr_db(&expected, &results[0]);
            assert!(s > min_db, "{spec}: SQNR {s} dB");
        }
    }

    #[test]
    fn agrees_with_twostep_quality() {
        // Hier has 3 QDQ rounds vs two-step's 2: a small, bounded quality
        // cost (the price of the 4x cross-NUMA volume saving).
        let topo = Topology::new(presets::l40(), 8);
        let codec = Codec::parse("int4@32").unwrap();
        let (hier_r, expected) = harness(&topo, 8192, &codec, allreduce);
        let (two_r, _) = harness(&topo, 8192, &codec, super::super::twostep::allreduce);
        let hier_s = sqnr_db(&expected, &hier_r[0]);
        let two_s = sqnr_db(&expected, &two_r[0]);
        assert!(hier_s > two_s - 4.5, "hier {hier_s} dB vs two-step {two_s} dB");
        assert!(hier_s < two_s + 1.0, "hier cannot beat two-step");
    }

    #[test]
    fn cross_numa_volume_is_2m_measured() {
        // The fabric measures the *physical* floor: M/s per rank in each
        // bridge direction = 2M total. Table 5's "M" counts the reduction
        // direction only (the paper's accounting) — see sim::volume.
        let topo = Topology::new(presets::l40(), 8);
        let len = 4096usize;
        let inputs: Vec<f32> = (0..len).map(|i| i as f32 * 0.5).collect();
        let ir = &inputs;
        let (_, counters) = run_ranks(&topo, |h| {
            let mut c = Communicator::from_handle(h);
            let mut data = ir.clone();
            allreduce(&mut c, &mut data, &Codec::Bf16).unwrap();
        });
        let m = 2.0 * len as f64;
        let cross = counters.cross_numa_bytes() as f64;
        assert!((cross / (2.0 * m) - 1.0).abs() < 0.05, "cross {cross} vs 2M {}", 2.0 * m);
        // 4x less than two-step's measured 8M (4M per direction).
        let total = counters.total_bytes() as f64;
        assert!((total / (14.0 * m) - 1.0).abs() < 0.05, "total {total}");
    }

    #[test]
    fn cross_numa_far_below_twostep() {
        let topo = Topology::new(presets::l40(), 8);
        let len = 4096usize;
        type AlgoFn = fn(
            &mut Communicator,
            &mut [f32],
            &Codec,
        ) -> Result<(), CommError>;
        let run = |f: AlgoFn| {
            let inputs: Vec<f32> = (0..len).map(|i| i as f32).collect();
            let ir = &inputs;
            let (_, c) = run_ranks(&topo, |h| {
                let mut comm = Communicator::from_handle(h);
                let mut data = ir.clone();
                f(&mut comm, &mut data, &Codec::Bf16).unwrap();
            });
            c.cross_numa_bytes() as f64
        };
        let two = run(super::super::twostep::allreduce);
        let hier = run(allreduce);
        // Table 5: 4M vs M per direction — a 4x saving either way you count.
        assert!((two / hier - 4.0).abs() < 0.2, "two-step {two} vs hier {hier}");
    }

    #[test]
    fn works_on_4_gpus() {
        let topo = Topology::new(presets::l40(), 4); // 2 groups of 2
        let (results, expected) = harness(&topo, 513, &Codec::parse("int8").unwrap(), allreduce);
        let s = sqnr_db(&expected, &results[0]);
        assert!(s > 24.0, "SQNR {s}");
    }

    #[test]
    fn flat_topology_is_a_clean_error() {
        let topo = Topology::new(presets::h800(), 4);
        let (errs, _) = run_ranks(&topo, |h| {
            let mut c = Communicator::from_handle(h);
            let mut data = vec![1.0f32; 64];
            allreduce(&mut c, &mut data, &Codec::Bf16).unwrap_err().to_string()
        });
        assert!(errs[0].contains("NUMA"), "{}", errs[0]);
    }
}
