//! The collective layer's error taxonomy.
//!
//! Every [`Communicator`](super::Communicator) method returns
//! `Result<_, CommError>` instead of panicking: a broken link, a corrupted
//! payload, or an algorithm/topology mismatch surfaces as a typed error the
//! caller can report or retry around, and the variant says *which layer*
//! failed:
//!
//! | variant      | layer                | typical cause                          |
//! |--------------|----------------------|----------------------------------------|
//! | [`Send`]     | transport            | peer disconnected mid-collective       |
//! | [`Recv`]     | transport / frame    | CRC failure, version or seq mismatch   |
//! | [`Decode`]   | quant wire format    | truncated or corrupted payload body    |
//! | [`Header`]   | quant wire format    | self-describing header contradicts the |
//! |              |                      | delivered frame (e.g. inflated `n`)    |
//! | [`Topology`] | algorithm selection  | hierarchical algo on a non-NUMA node   |
//! | [`Shape`]    | caller arguments     | wrong payload count / rank out of range|
//! | [`PeerLost`] | session fabric       | peer crashed / heartbeat deadline hit  |
//! | [`Rendezvous`]| session bootstrap   | dead root, handshake timeout, bad greeting|
//!
//! [`Send`]: CommError::Send
//! [`Recv`]: CommError::Recv
//! [`Decode`]: CommError::Decode
//! [`Header`]: CommError::Header
//! [`Topology`]: CommError::Topology
//! [`Shape`]: CommError::Shape
//! [`PeerLost`]: CommError::PeerLost
//! [`Rendezvous`]: CommError::Rendezvous

use std::fmt;

use super::Algo;

/// Why a collective could not complete. See the module docs for the
/// layer-by-layer taxonomy.
#[derive(Debug)]
pub enum CommError {
    /// The transport failed to hand a payload to `peer`.
    Send { peer: usize, source: anyhow::Error },
    /// The transport failed to produce the next payload from `peer`
    /// (frame corruption, version mismatch, sequence desync, disconnect).
    Recv { peer: usize, source: anyhow::Error },
    /// A delivered payload failed quant-wire decoding.
    Decode { peer: usize, source: anyhow::Error },
    /// A payload's self-describing header contradicts the delivered frame.
    Header { peer: usize, detail: String },
    /// The selected algorithm cannot run on this topology.
    Topology { algo: Algo, detail: String },
    /// Caller-side argument error (payload count, rank range, length).
    Shape { detail: String },
    /// The session fabric declared `rank` dead under `epoch` — its
    /// heartbeat deadline expired, its socket closed abruptly, or a fault
    /// injector killed it. Survivors receive this within the configured
    /// timeout instead of blocking forever; recovery options are a
    /// degraded-membership re-plan or a rejoin under `epoch + 1` (see
    /// [`crate::session`]).
    PeerLost { rank: usize, epoch: u16 },
    /// The rendezvous handshake with `--root` failed or timed out (dead
    /// root, refused connection, malformed greeting, epoch conflict).
    Rendezvous { detail: String },
}

impl CommError {
    pub(crate) fn send(peer: usize, source: anyhow::Error) -> CommError {
        CommError::Send { peer, source }
    }

    pub(crate) fn recv(peer: usize, source: anyhow::Error) -> CommError {
        CommError::Recv { peer, source }
    }

    pub(crate) fn decode(peer: usize, source: anyhow::Error) -> CommError {
        CommError::Decode { peer, source }
    }

    pub(crate) fn header(peer: usize, detail: impl Into<String>) -> CommError {
        CommError::Header { peer, detail: detail.into() }
    }

    pub(crate) fn topology(algo: Algo, detail: impl Into<String>) -> CommError {
        CommError::Topology { algo, detail: detail.into() }
    }

    pub(crate) fn shape(detail: impl Into<String>) -> CommError {
        CommError::Shape { detail: detail.into() }
    }

    pub(crate) fn peer_lost(rank: usize, epoch: u16) -> CommError {
        CommError::PeerLost { rank, epoch }
    }

    pub(crate) fn rendezvous(detail: impl Into<String>) -> CommError {
        CommError::Rendezvous { detail: detail.into() }
    }

    /// The peer rank the failure is attributed to, if any.
    pub fn peer(&self) -> Option<usize> {
        match self {
            CommError::Send { peer, .. }
            | CommError::Recv { peer, .. }
            | CommError::Decode { peer, .. }
            | CommError::Header { peer, .. } => Some(*peer),
            CommError::PeerLost { rank, .. } => Some(*rank),
            CommError::Topology { .. } | CommError::Shape { .. } | CommError::Rendezvous { .. } => {
                None
            }
        }
    }
}

impl fmt::Display for CommError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CommError::Send { peer, source } => {
                write!(f, "send to rank {peer} failed: {source}")
            }
            CommError::Recv { peer, source } => {
                write!(f, "recv from rank {peer} failed: {source}")
            }
            CommError::Decode { peer, source } => {
                write!(f, "payload from rank {peer} failed to decode: {source}")
            }
            CommError::Header { peer, detail } => {
                write!(f, "payload from rank {peer} has an inconsistent header: {detail}")
            }
            CommError::Topology { algo, detail } => {
                write!(f, "{} cannot run on this topology: {detail}", algo.name())
            }
            CommError::Shape { detail } => write!(f, "invalid collective arguments: {detail}"),
            CommError::PeerLost { rank, epoch } => {
                write!(f, "PeerLost: rank {rank} lost from the session (epoch {epoch})")
            }
            CommError::Rendezvous { detail } => write!(f, "rendezvous failed: {detail}"),
        }
    }
}

impl std::error::Error for CommError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            CommError::Send { source, .. }
            | CommError::Recv { source, .. }
            | CommError::Decode { source, .. } => Some(source.as_ref()),
            _ => None,
        }
    }
}

/// Topology construction failures are caller-argument errors at the
/// collective layer (a mistyped `--gpus`/`--groups` shape): the typed
/// [`TopologyError`](crate::topo::TopologyError) detail is preserved in the
/// message and the whole chain stays `anyhow`-compatible.
impl From<crate::topo::TopologyError> for CommError {
    fn from(e: crate::topo::TopologyError) -> CommError {
        CommError::Shape { detail: e.to_string() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_names_the_layer_and_peer() {
        let e = CommError::recv(3, anyhow::anyhow!("CRC mismatch"));
        let s = e.to_string();
        assert!(s.contains("rank 3") && s.contains("CRC"), "{s}");
        assert_eq!(e.peer(), Some(3));

        let t = CommError::topology(Algo::Hier, "1 NUMA group".into());
        assert!(t.to_string().contains("Hierarchical"), "{t}");
        assert_eq!(t.peer(), None);
    }

    #[test]
    fn peer_lost_and_rendezvous_display() {
        let e = CommError::peer_lost(5, 2);
        let s = e.to_string();
        assert!(s.contains("PeerLost") && s.contains("rank 5") && s.contains("epoch 2"), "{s}");
        assert_eq!(e.peer(), Some(5));

        let r = CommError::rendezvous("root 127.0.0.1:9999 unreachable");
        assert!(r.to_string().contains("rendezvous failed"), "{r}");
        assert_eq!(r.peer(), None);
    }

    #[test]
    fn converts_into_anyhow() {
        fn takes_anyhow() -> anyhow::Result<()> {
            Err(CommError::shape("bad"))?
        }
        let e = takes_anyhow().unwrap_err();
        assert!(e.to_string().contains("invalid collective arguments"));
    }
}
