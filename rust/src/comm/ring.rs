//! Ring AllReduce — the NCCL baseline.
//!
//! Classic 2(N−1)-step ring: N−1 reduce-scatter hops, N−1 all-gather hops.
//! The paper runs this in BF16 only; a quantizing codec is kept as an
//! *ablation* that demonstrates why the paper's two-step exists — each
//! hop re-quantizes the partial sum, so quantization error compounds N−1
//! times (see `quantized_ring_error_compounds` below). For the same reason
//! `AlgoPolicy::Auto` never selects the ring for a lossy codec.

use super::{chunk_range, communicator::Communicator, encode, error::CommError};
use crate::quant::Codec;
use crate::record;
use crate::telemetry::{codec_tag, Op, Stage};
use crate::transport::Transport;

/// In-place ring AllReduce of `data` across all ranks.
///
/// Every rank ends with (a wire-precision image of) the element-wise sum.
pub(crate) fn allreduce<T: Transport>(
    c: &mut Communicator<T>,
    data: &mut [f32],
    codec: &Codec,
) -> Result<(), CommError> {
    let Communicator { handle: h, bufs, scratch, codec_threads, .. } = c;
    let t = *codec_threads;
    let n = h.n;
    if n == 1 {
        return Ok(());
    }
    let next = (h.rank + 1) % n;
    let prev = (h.rank + n - 1) % n;
    if let Some(rec) = h.recorder() {
        rec.set_stage(Stage::Single, codec_tag(codec));
    }

    // Reduce-scatter: after N-1 hops, rank owns the full sum of chunk
    // (rank + 1) % n.
    for step in 0..n - 1 {
        let send_c = (h.rank + n - step) % n;
        let recv_c = (h.rank + n - step - 1) % n;
        let sr = chunk_range(data.len(), n, send_c);
        record!(h.recorder(), start Op::Encode, sr.len() as u64);
        let wire_out = encode(codec, &data[sr], bufs, t)?;
        record!(h.recorder(), end Op::Encode, wire_out.len() as u64);
        h.send(next, wire_out)?;
        let wire = h.recv(prev)?;
        let rr = chunk_range(data.len(), n, recv_c);
        scratch.resize(rr.len(), 0.0);
        scratch.copy_from_slice(&data[rr.clone()]);
        record!(h.recorder(), start Op::DecodeSum, scratch.len() as u64);
        Codec::decode_sum_with_threads(&wire, bufs, scratch, t)
            .map_err(|e| CommError::decode(prev, e))?;
        record!(h.recorder(), end Op::DecodeSum, wire.len() as u64);
        data[rr].copy_from_slice(scratch);
    }

    // All-gather: circulate the reduced chunks. The owned chunk also takes
    // one QDQ so every rank ends bit-identical.
    let own = (h.rank + 1) % n;
    {
        let or = chunk_range(data.len(), n, own);
        record!(h.recorder(), start Op::Encode, or.len() as u64);
        let wire = encode(codec, &data[or.clone()], bufs, t)?;
        record!(h.recorder(), end Op::Encode, wire.len() as u64);
        scratch.resize(or.len(), 0.0);
        record!(h.recorder(), start Op::Decode, scratch.len() as u64);
        Codec::decode_with_threads(&wire, bufs, scratch, t)
            .map_err(|e| CommError::decode(h.rank, e))?;
        record!(h.recorder(), end Op::Decode, wire.len() as u64);
        data[or].copy_from_slice(scratch);
    }
    for step in 0..n - 1 {
        let send_c = (h.rank + 1 + n - step) % n;
        let recv_c = (h.rank + n - step) % n;
        let sr = chunk_range(data.len(), n, send_c);
        record!(h.recorder(), start Op::Encode, sr.len() as u64);
        let wire_out = encode(codec, &data[sr], bufs, t)?;
        record!(h.recorder(), end Op::Encode, wire_out.len() as u64);
        h.send(next, wire_out)?;
        let wire = h.recv(prev)?;
        let rr = chunk_range(data.len(), n, recv_c);
        scratch.resize(rr.len(), 0.0);
        record!(h.recorder(), start Op::Decode, scratch.len() as u64);
        Codec::decode_with_threads(&wire, bufs, scratch, t)
            .map_err(|e| CommError::decode(prev, e))?;
        record!(h.recorder(), end Op::Decode, wire.len() as u64);
        data[rr].copy_from_slice(scratch);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::fabric::run_ranks;
    use crate::comm::testutil::harness;
    use crate::quant::Codec;
    use crate::topo::{presets, Topology};
    use crate::util::stats::sqnr_db;

    #[test]
    fn bf16_ring_matches_serial_sum() {
        let topo = Topology::new(presets::h800(), 8);
        let (results, expected) = harness(&topo, 1000, &Codec::Bf16, allreduce);
        for r in &results {
            assert_eq!(r, &results[0], "all ranks must agree bitwise");
        }
        let s = sqnr_db(&expected, &results[0]);
        assert!(s > 35.0, "bf16 ring SQNR {s} dB");
    }

    #[test]
    fn works_for_odd_sizes_and_small_n() {
        for n in [2usize, 3, 5] {
            let topo = Topology::new(presets::h800(), n);
            let (results, expected) = harness(&topo, 97, &Codec::Bf16, allreduce);
            let s = sqnr_db(&expected, &results[0]);
            assert!(s > 30.0, "n={n} SQNR {s}");
        }
    }

    #[test]
    fn quantized_ring_error_compounds() {
        // The ablation: INT8 on the ring loses badly to INT8 on the
        // two-step because every hop re-quantizes the partial sum.
        let topo = Topology::new(presets::h800(), 8);
        let codec = Codec::parse("int8").unwrap();
        let (ring_r, expected) = harness(&topo, 4096, &codec, allreduce);
        let (two_r, _) = harness(&topo, 4096, &codec, super::super::twostep::allreduce);
        let ring_s = sqnr_db(&expected, &ring_r[0]);
        let two_s = sqnr_db(&expected, &two_r[0]);
        assert!(
            two_s > ring_s + 3.0,
            "two-step {two_s} dB must beat quantized ring {ring_s} dB"
        );
    }

    #[test]
    fn table5_ring_volume() {
        // NCCL row of Table 5: total 2(N-1)M = 14M.
        let topo = Topology::new(presets::l40(), 8);
        let len = 4096usize;
        let m = (Codec::Bf16.wire_len(len / 8)) as f64 * 8.0; // per-GPU wire bytes
        let inputs: Vec<f32> = vec![1.0; len];
        let ir = &inputs;
        let (_, counters) = run_ranks(&topo, |h| {
            let mut c = Communicator::from_handle(h);
            let mut data = ir.clone();
            allreduce(&mut c, &mut data, &Codec::Bf16).unwrap();
        });
        let total = counters.total_bytes() as f64;
        // 8 ranks each send 14 chunks of ~M/8 wire bytes.
        assert!((total / (14.0 * m) - 1.0).abs() < 0.05, "total {total} vs 14M {}", 14.0 * m);
    }
}
