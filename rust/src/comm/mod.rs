//! Quantized collectives over the pluggable transport fabric.
//!
//! Every algorithm moves real encoded payloads ([`crate::quant::Codec`]
//! wire format) between ranks: quantize → bit-split pack → transfer →
//! unpack → dequantize → reduce. Each collective is generic over the
//! [`crate::transport::Transport`] backend, so the same code runs over
//! thread ranks (in-process mpsc mesh, [`fabric::run_ranks`]) and over OS
//! processes on real sockets (`flashcomm worker`); the results are
//! bit-identical across backends. This is the functional half of the
//! reproduction (numerics, wire format, QDQ placement); the timing half
//! lives in [`crate::sim`].
//!
//! | paper concept                  | implementation            |
//! |--------------------------------|---------------------------|
//! | NCCL ring AllReduce            | [`ring::allreduce`]       |
//! | Flash-Comm V1 two-step         | [`twostep::allreduce`]    |
//! | hierarchical two-step (Fig. 6) | [`hier::allreduce`]       |
//! | + pipeline parallelism (Fig. 8)| [`pipeline::allreduce`]   |
//! | EP dispatch All2All            | [`all2all::all2all`]      |

pub mod all2all;
pub mod fabric;
pub mod hier;
pub mod pipeline;
pub mod ring;
pub mod twostep;

use crate::comm::fabric::RankHandle;
use crate::quant::{Codec, CodecBuffers};
use crate::sim::Algo;
use crate::transport::Transport;

/// Run the `algo`-selected AllReduce in place — the one dispatch point
/// shared by the trainer and the `worker` CLI.
pub fn allreduce_with<T: Transport>(
    algo: Algo,
    h: &RankHandle<T>,
    data: &mut [f32],
    codec: &Codec,
) {
    match algo {
        Algo::Ring => ring::allreduce(h, data, codec),
        Algo::TwoStep => twostep::allreduce(h, data, codec),
        Algo::Hier => hier::allreduce(h, data, codec),
        Algo::HierPipelined => pipeline::allreduce(h, data, codec),
    }
}

/// Balanced contiguous partition: the `i`-th of `parts` chunks of `len`.
pub fn chunk_range(len: usize, parts: usize, i: usize) -> std::ops::Range<usize> {
    debug_assert!(i < parts);
    let base = len / parts;
    let rem = len % parts;
    let start = i * base + i.min(rem);
    let extra = usize::from(i < rem);
    start..start + base + extra
}

/// Encode a slice with scratch reuse (helper shared by the collectives).
pub(crate) fn encode(codec: &Codec, data: &[f32], bufs: &mut CodecBuffers) -> Vec<u8> {
    let mut out = Vec::with_capacity(codec.wire_len(data.len()));
    codec.encode_with(data, bufs, &mut out);
    out
}

#[cfg(test)]
pub(crate) mod testutil {
    use crate::comm::fabric::{run_ranks, RankHandle};
    use crate::quant::Codec;
    use crate::topo::Topology;
    use crate::util::Prng;

    /// Run an allreduce over heavy-tailed per-rank data; return the
    /// per-rank results and the exact serial sum.
    pub(crate) fn harness(
        topo: &Topology,
        len: usize,
        codec: &Codec,
        f: impl Fn(&RankHandle, &mut [f32], &Codec) + Sync,
    ) -> (Vec<Vec<f32>>, Vec<f32>) {
        let n = topo.n_gpus;
        let inputs: Vec<Vec<f32>> = (0..n)
            .map(|r| {
                let mut rng = Prng::new(1000 + r as u64);
                let mut v = vec![0f32; len];
                rng.fill_activations(&mut v, 1.0);
                v
            })
            .collect();
        let mut expected = vec![0f32; len];
        for v in &inputs {
            for (e, x) in expected.iter_mut().zip(v) {
                *e += *x;
            }
        }
        let inputs_ref = &inputs;
        let (results, _) = run_ranks(topo, |h| {
            let mut data = inputs_ref[h.rank].clone();
            f(&h, &mut data, codec);
            data
        });
        (results, expected)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunk_range_covers_exactly() {
        for len in [0usize, 1, 7, 8, 100, 1000] {
            for parts in [1usize, 2, 3, 8] {
                let mut covered = 0;
                for i in 0..parts {
                    let r = chunk_range(len, parts, i);
                    assert_eq!(r.start, covered, "len {len} parts {parts} i {i}");
                    covered = r.end;
                }
                assert_eq!(covered, len);
            }
        }
    }

    #[test]
    fn chunk_range_is_balanced() {
        for i in 0..8 {
            let r = chunk_range(100, 8, i);
            assert!(r.len() == 12 || r.len() == 13);
        }
    }
}
