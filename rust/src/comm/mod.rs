//! Quantized collectives over the pluggable transport fabric.
//!
//! The front door is [`Communicator`]: one NCCL-style handle per rank that
//! owns the rank's transport endpoint, the node [`Topology`], the shared
//! byte counters, and persistent codec scratch, and exposes the collectives
//! as fallible methods — `allreduce`, `reduce_scatter`, `all_gather`,
//! `broadcast`, `all2all` — all returning `Result<_, `[`CommError`]`>`.
//!
//! Every algorithm moves real encoded payloads ([`crate::quant::Codec`]
//! wire format) between ranks: quantize → bit-split pack → transfer →
//! unpack → dequantize → reduce. The communicator is generic over the
//! [`crate::transport::Transport`] backend, so the same code runs over
//! thread ranks (in-process mpsc mesh, [`fabric::run_ranks`]) and over OS
//! processes on real sockets (`flashcomm worker`); the results are
//! bit-identical across backends. This is the functional half of the
//! reproduction (numerics, wire format, QDQ placement); the timing half
//! lives in [`crate::sim`].
//!
//! Which AllReduce algorithm runs is an [`AlgoPolicy`]: pin one with
//! `Fixed(`[`Algo`]`)`, or let `Auto` consult the calibrated cost model
//! ([`crate::sim::allreduce_time`]) per call — hierarchical wins above the
//! crossover payload size on NUMA nodes, the one-shot two-step below it
//! (see DESIGN.md §7 for the crossover table).
//!
//! | paper concept                  | implementation                     |
//! |--------------------------------|------------------------------------|
//! | NCCL ring AllReduce            | [`Algo::Ring`]                     |
//! | Flash-Comm V1 two-step         | [`Algo::TwoStep`]                  |
//! | hierarchical two-step (Fig. 6) | [`Algo::Hier`]                     |
//! | + pipeline parallelism (Fig. 8)| [`Algo::HierPipelined`]            |
//! | EP dispatch All2All            | [`Communicator::all2all`]          |

pub mod communicator;
pub mod error;
pub mod fabric;

pub(crate) mod all2all;
pub(crate) mod hier;
pub(crate) mod pipeline;
pub(crate) mod ring;
pub(crate) mod twostep;

use std::str::FromStr;

pub use communicator::{
    preset_topo, preset_topo_custom, preset_topo_grouped, Communicator, LocalGroup,
};
pub use error::CommError;
pub use pipeline::{DEFAULT_CHUNKS, SEND_WINDOW};

use crate::quant::{Codec, CodecBuffers};
use crate::topo::Topology;

/// AllReduce algorithm families the paper compares. This is the type's
/// home; [`crate::sim::volume`] re-exports it for the timing model.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Algo {
    /// NCCL-style ring (reduce-scatter + all-gather around a ring).
    Ring,
    /// Flash Communication V1 one-shot two-step (RS + AG, all-to-all style).
    TwoStep,
    /// Hierarchical two-step: intra-NUMA RS → cross-NUMA reduce → intra AG.
    Hier,
    /// Hierarchical two-step with micro-chunk pipeline parallelism (Fig. 8).
    HierPipelined,
}

impl Algo {
    /// Paper-style display name (table rows).
    pub fn name(&self) -> &'static str {
        match self {
            Algo::Ring => "NCCL",
            Algo::TwoStep => "Two-step",
            Algo::Hier => "Hierarchical Two-step",
            Algo::HierPipelined => "Hierarchical Two-step + PP",
        }
    }

    /// CLI token (what `--algo` takes; the inverse of [`FromStr`]).
    pub fn token(&self) -> &'static str {
        match self {
            Algo::Ring => "ring",
            Algo::TwoStep => "twostep",
            Algo::Hier => "hier",
            Algo::HierPipelined => "hierpp",
        }
    }

    /// Can this algorithm run on `topo`? **The** admissibility definition:
    /// [`AlgoPolicy::Auto`] candidate selection, every collective's runtime
    /// guard, and the early CLI validation all derive from this one method
    /// — duplicated knowledge here is exactly how Auto used to be able to
    /// select an algorithm whose collective then refused to run.
    ///
    /// Ring and two-step run on any topology. The hierarchical family
    /// needs `G >= 2` link-tier groups joined by an inter-group link (2-
    /// or 4-group PCIe boxes, multi-node NVLink clusters); whether a
    /// *quantized* ring is ever worth running is a policy question (`Auto`
    /// never picks one — error compounds over N−1 hops), not an
    /// admissibility one: `Fixed(Ring)` with a codec remains the ablation.
    pub fn admissible(&self, topo: &Topology) -> Result<(), CommError> {
        match self {
            Algo::Ring | Algo::TwoStep => Ok(()),
            Algo::Hier | Algo::HierPipelined => {
                if topo.numa_groups >= 2 && topo.inter_bw().is_some() {
                    Ok(())
                } else {
                    Err(CommError::topology(
                        *self,
                        format!(
                            "needs >= 2 NUMA/link-tier groups joined by an inter-group \
                             link, topology has {} flat group(s)",
                            topo.numa_groups
                        ),
                    ))
                }
            }
        }
    }
}

impl std::fmt::Display for Algo {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.token())
    }
}

impl FromStr for Algo {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> anyhow::Result<Algo> {
        Ok(match s.trim().to_ascii_lowercase().as_str() {
            "ring" | "nccl" => Algo::Ring,
            "twostep" | "two-step" => Algo::TwoStep,
            "hier" => Algo::Hier,
            "hierpp" | "hier-pp" => Algo::HierPipelined,
            other => anyhow::bail!(
                "unknown algo '{other}' (expected ring|twostep|hier|hierpp|auto)"
            ),
        })
    }
}

/// How a [`Communicator`] picks the AllReduce algorithm for a call.
///
/// This is now a thin shim over the plan layer ([`crate::plan`]): both
/// arms build a *uniform* [`crate::plan::CommPlan`] (one codec for every
/// stage, the default chunk count and send window) and run it through the
/// same plan execution path as [`crate::plan::PlanPolicy`]. Use
/// `PlanPolicy` (CLI `--plan`) to mix stage codecs or tune the pipelined
/// knobs; `AlgoPolicy` remains the stable "pick an algorithm, keep my
/// codec everywhere" surface.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AlgoPolicy {
    /// Always run this algorithm (error if the topology cannot host it).
    Fixed(Algo),
    /// Consult the calibrated cost model per call: time every algorithm
    /// admissible on the topology ([`Algo::admissible`]) for this (codec,
    /// payload size) and take the fastest. Deterministic — a pure function
    /// of (topology, codec, size). A quantized ring is never a candidate
    /// (its quantization error compounds over N−1 hops; the paper runs the
    /// ring in BF16 only).
    Auto,
}

impl AlgoPolicy {
    /// The algorithm this policy runs for `elems` f32 values on `topo`.
    pub fn resolve(&self, topo: &Topology, codec: &Codec, elems: usize) -> Algo {
        match *self {
            AlgoPolicy::Fixed(a) => a,
            AlgoPolicy::Auto => {
                let m_bytes = 2.0 * elems as f64; // sim convention: BF16 payload bytes
                let mut candidates = Vec::with_capacity(4);
                if matches!(codec, Codec::Bf16) {
                    candidates.push(Algo::Ring);
                }
                candidates.push(Algo::TwoStep);
                for a in [Algo::Hier, Algo::HierPipelined] {
                    if a.admissible(topo).is_ok() {
                        candidates.push(a);
                    }
                }
                let mut best = candidates[0];
                let mut best_t = f64::INFINITY;
                for a in candidates {
                    let t = crate::sim::allreduce_time(topo, a, codec, m_bytes).total();
                    if t < best_t {
                        best_t = t;
                        best = a;
                    }
                }
                best
            }
        }
    }
}

impl std::fmt::Display for AlgoPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AlgoPolicy::Fixed(a) => f.write_str(a.token()),
            AlgoPolicy::Auto => f.write_str("auto"),
        }
    }
}

impl FromStr for AlgoPolicy {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> anyhow::Result<AlgoPolicy> {
        if s.trim().eq_ignore_ascii_case("auto") {
            Ok(AlgoPolicy::Auto)
        } else {
            Ok(AlgoPolicy::Fixed(s.parse()?))
        }
    }
}

/// Balanced contiguous partition: the `i`-th of `parts` chunks of `len`.
pub fn chunk_range(len: usize, parts: usize, i: usize) -> std::ops::Range<usize> {
    debug_assert!(i < parts);
    let base = len / parts;
    let rem = len % parts;
    let start = i * base + i.min(rem);
    let extra = usize::from(i < rem);
    start..start + base + extra
}

/// Encode a slice with scratch reuse (helper shared by the collectives).
/// `threads` is the communicator's codec worker budget — the fused kernels
/// chunk large payloads across that many scoped threads. A payload the
/// wire header cannot carry (`> u32::MAX` elements) is a clean
/// [`CommError::Shape`], never a silently truncated on-wire count.
pub(crate) fn encode(
    codec: &Codec,
    data: &[f32],
    bufs: &mut CodecBuffers,
    threads: usize,
) -> Result<Vec<u8>, CommError> {
    let mut out = Vec::with_capacity(codec.wire_len(data.len()));
    codec
        .encode_with_threads(data, bufs, &mut out, threads)
        .map_err(|e| CommError::shape(e.to_string()))?;
    Ok(out)
}

#[cfg(test)]
pub(crate) mod testutil {
    use crate::comm::error::CommError;
    use crate::comm::fabric::run_ranks;
    use crate::comm::Communicator;
    use crate::quant::Codec;
    use crate::topo::Topology;
    use crate::transport::InProcTransport;
    use crate::util::Prng;

    /// Run an allreduce over heavy-tailed per-rank data; return the
    /// per-rank results and the exact serial sum.
    pub(crate) fn harness(
        topo: &Topology,
        len: usize,
        codec: &Codec,
        f: impl Fn(&mut Communicator<InProcTransport>, &mut [f32], &Codec) -> Result<(), CommError>
            + Sync,
    ) -> (Vec<Vec<f32>>, Vec<f32>) {
        let n = topo.n_gpus;
        let inputs: Vec<Vec<f32>> = (0..n)
            .map(|r| {
                let mut rng = Prng::new(1000 + r as u64);
                let mut v = vec![0f32; len];
                rng.fill_activations(&mut v, 1.0);
                v
            })
            .collect();
        let mut expected = vec![0f32; len];
        for v in &inputs {
            for (e, x) in expected.iter_mut().zip(v) {
                *e += *x;
            }
        }
        let inputs_ref = &inputs;
        let (results, _) = run_ranks(topo, |h| {
            let mut comm = Communicator::from_handle(h);
            let mut data = inputs_ref[comm.rank()].clone();
            f(&mut comm, &mut data, codec).expect("collective failed");
            data
        });
        (results, expected)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunk_range_covers_exactly() {
        for len in [0usize, 1, 7, 8, 100, 1000] {
            for parts in [1usize, 2, 3, 8] {
                let mut covered = 0;
                for i in 0..parts {
                    let r = chunk_range(len, parts, i);
                    assert_eq!(r.start, covered, "len {len} parts {parts} i {i}");
                    covered = r.end;
                }
                assert_eq!(covered, len);
            }
        }
    }

    #[test]
    fn chunk_range_is_balanced() {
        for i in 0..8 {
            let r = chunk_range(100, 8, i);
            assert!(r.len() == 12 || r.len() == 13);
        }
    }

    #[test]
    fn algo_parses_and_roundtrips() {
        for a in [Algo::Ring, Algo::TwoStep, Algo::Hier, Algo::HierPipelined] {
            assert_eq!(a.token().parse::<Algo>().unwrap(), a);
        }
        assert_eq!("NCCL".parse::<Algo>().unwrap(), Algo::Ring);
        assert_eq!("hier-pp".parse::<Algo>().unwrap(), Algo::HierPipelined);
        assert!("allgatherify".parse::<Algo>().is_err());
    }

    #[test]
    fn admissibility_matrix() {
        use crate::topo::{presets, Topology};
        let flat = Topology::new(presets::h800(), 8);
        let numa2 = Topology::new(presets::l40(), 8);
        let numa4 = presets::four_group_pcie(8).unwrap();
        let duo = presets::dual_nvlink_node(16).unwrap();
        for a in [Algo::Ring, Algo::TwoStep] {
            for t in [&flat, &numa2, &numa4, &duo] {
                assert!(a.admissible(t).is_ok(), "{a} on {}x{}", t.spec.name, t.numa_groups);
            }
        }
        for a in [Algo::Hier, Algo::HierPipelined] {
            assert!(a.admissible(&flat).is_err(), "{a} needs groups");
            for t in [&numa2, &numa4, &duo] {
                assert!(a.admissible(t).is_ok(), "{a} on {}x{}", t.spec.name, t.numa_groups);
            }
            // A NUMA *device* flattened to one group is still inadmissible:
            // admissibility is a property of the topology, not the spec.
            let flat_l40 = Topology::with_groups(presets::l40(), 8, 1);
            let err = a.admissible(&flat_l40).unwrap_err();
            assert!(matches!(err, CommError::Topology { algo, .. } if algo == a), "{err}");
        }
    }

    #[test]
    fn policy_parses_auto_and_fixed() {
        assert_eq!("auto".parse::<AlgoPolicy>().unwrap(), AlgoPolicy::Auto);
        assert_eq!("AUTO".parse::<AlgoPolicy>().unwrap(), AlgoPolicy::Auto);
        assert_eq!(
            "twostep".parse::<AlgoPolicy>().unwrap(),
            AlgoPolicy::Fixed(Algo::TwoStep)
        );
        assert!("fastest".parse::<AlgoPolicy>().is_err());
        assert_eq!(AlgoPolicy::Auto.to_string(), "auto");
        assert_eq!(AlgoPolicy::Fixed(Algo::Hier).to_string(), "hier");
    }
}
