//! One-shot collectives: Flash Communication V1 two-step AllReduce with
//! fused quantization, and the primitives it composes from.
//!
//! The two-step is literally [`reduce_scatter`] ∘ [`all_gather`]: a
//! one-shot reduce-scatter (every rank sends chunk *c* directly to rank
//! *c*), local dequantize-reduce, then a one-shot all-gather of the
//! reduced chunks. Exactly two QDQ rounds regardless of N — the property
//! that makes aggressive quantization usable at all (vs. the ring's N−1
//! compounding rounds). [`broadcast`] is the root-sourced one-shot,
//! exposed for weight/state distribution through the same wire codec.

use super::{chunk_range, communicator::Communicator, encode, error::CommError};
use crate::quant::Codec;
use crate::record;
use crate::telemetry::{codec_tag, Op, Stage};
use crate::transport::Transport;

/// In-place two-step AllReduce of `data` across all ranks.
pub(crate) fn allreduce<T: Transport>(
    c: &mut Communicator<T>,
    data: &mut [f32],
    codec: &Codec,
) -> Result<(), CommError> {
    reduce_scatter(c, data, codec)?;
    all_gather(c, data, codec)
}

/// One-shot reduce-scatter: chunk `r` of `data` goes to rank `r`; this
/// rank's chunk (the returned range) ends holding the reduced sum — own
/// contribution at full precision plus the decoded wire images of every
/// peer's, accumulated in rank order. The rest of `data` is untouched.
pub(crate) fn reduce_scatter<T: Transport>(
    c: &mut Communicator<T>,
    data: &mut [f32],
    codec: &Codec,
) -> Result<std::ops::Range<usize>, CommError> {
    let Communicator { handle: h, bufs, acc, codec_threads, .. } = c;
    let t = *codec_threads;
    let n = h.n;
    let own = chunk_range(data.len(), n, h.rank);
    if n == 1 {
        return Ok(own);
    }
    if let Some(rec) = h.recorder() {
        rec.set_stage(Stage::ReduceScatter, codec_tag(codec));
    }
    for dst in 0..n {
        if dst != h.rank {
            let r = chunk_range(data.len(), n, dst);
            record!(h.recorder(), start Op::Encode, r.len() as u64);
            let wire = encode(codec, &data[r], bufs, t)?;
            record!(h.recorder(), end Op::Encode, wire.len() as u64);
            h.send(dst, wire)?;
        }
    }
    acc.clear();
    acc.extend_from_slice(&data[own.clone()]);
    for src in 0..n {
        if src != h.rank {
            let wire = h.recv(src)?;
            record!(h.recorder(), start Op::DecodeSum, acc.len() as u64);
            Codec::decode_sum_with_threads(&wire, bufs, acc, t)
                .map_err(|e| CommError::decode(src, e))?;
            record!(h.recorder(), end Op::DecodeSum, wire.len() as u64);
        }
    }
    data[own.clone()].copy_from_slice(acc);
    Ok(own)
}

/// One-shot all-gather of every rank's owned chunk. The own chunk takes
/// the same QDQ as the copies on the wire so all ranks end bit-identical.
pub(crate) fn all_gather<T: Transport>(
    c: &mut Communicator<T>,
    data: &mut [f32],
    codec: &Codec,
) -> Result<(), CommError> {
    let Communicator { handle: h, bufs, codec_threads, .. } = c;
    let t = *codec_threads;
    let n = h.n;
    if n == 1 {
        return Ok(());
    }
    if let Some(rec) = h.recorder() {
        rec.set_stage(Stage::AllGather, codec_tag(codec));
    }
    let own = chunk_range(data.len(), n, h.rank);
    record!(h.recorder(), start Op::Encode, own.len() as u64);
    let wire = encode(codec, &data[own.clone()], bufs, t)?;
    record!(h.recorder(), end Op::Encode, wire.len() as u64);
    for dst in 0..n {
        if dst != h.rank {
            h.send(dst, wire.clone())?;
        }
    }
    record!(h.recorder(), start Op::Decode, own.len() as u64);
    Codec::decode_with_threads(&wire, bufs, &mut data[own], t)
        .map_err(|e| CommError::decode(h.rank, e))?;
    record!(h.recorder(), end Op::Decode, wire.len() as u64);
    for src in 0..n {
        if src != h.rank {
            let wire = h.recv(src)?;
            let r = chunk_range(data.len(), n, src);
            record!(h.recorder(), start Op::Decode, r.len() as u64);
            Codec::decode_with_threads(&wire, bufs, &mut data[r], t)
                .map_err(|e| CommError::decode(src, e))?;
            record!(h.recorder(), end Op::Decode, wire.len() as u64);
        }
    }
    Ok(())
}

/// Broadcast `root`'s `data` through the wire codec. Every rank — the root
/// included, via a self-QDQ — ends with the same wire-precision image.
pub(crate) fn broadcast<T: Transport>(
    c: &mut Communicator<T>,
    data: &mut [f32],
    root: usize,
    codec: &Codec,
) -> Result<(), CommError> {
    let Communicator { handle: h, bufs, codec_threads, .. } = c;
    let t = *codec_threads;
    let n = h.n;
    if root >= n {
        return Err(CommError::shape(format!("broadcast root {root} out of range 0..{n}")));
    }
    if n == 1 {
        return Ok(());
    }
    if let Some(rec) = h.recorder() {
        rec.set_stage(Stage::Single, codec_tag(codec));
    }
    if h.rank == root {
        record!(h.recorder(), start Op::Encode, data.len() as u64);
        let wire = encode(codec, data, bufs, t)?;
        record!(h.recorder(), end Op::Encode, wire.len() as u64);
        for dst in 0..n {
            if dst != root {
                h.send(dst, wire.clone())?;
            }
        }
        record!(h.recorder(), start Op::Decode, data.len() as u64);
        Codec::decode_with_threads(&wire, bufs, data, t)
            .map_err(|e| CommError::decode(root, e))?;
        record!(h.recorder(), end Op::Decode, wire.len() as u64);
    } else {
        let wire = h.recv(root)?;
        record!(h.recorder(), start Op::Decode, data.len() as u64);
        Codec::decode_with_threads(&wire, bufs, data, t)
            .map_err(|e| CommError::decode(root, e))?;
        record!(h.recorder(), end Op::Decode, wire.len() as u64);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::fabric::run_ranks;
    use crate::comm::testutil::harness;
    use crate::quant::Codec;
    use crate::topo::{presets, Topology};
    use crate::util::stats::sqnr_db;

    #[test]
    fn matches_serial_sum_across_codecs() {
        let topo = Topology::new(presets::h800(), 8);
        for (spec, min_db) in [
            ("bf16", 35.0),
            ("int8", 28.0),
            ("int6", 20.0),
            ("int5", 15.0),
            ("int4@32", 14.0),
            ("int3@32", 9.0),
            ("int2-sr@32", 6.0),
        ] {
            let codec = Codec::parse(spec).unwrap();
            let (results, expected) = harness(&topo, 2048, &codec, allreduce);
            for r in &results {
                assert_eq!(r, &results[0], "{spec}: ranks must agree");
            }
            let s = sqnr_db(&expected, &results[0]);
            assert!(s > min_db, "{spec}: SQNR {s} dB < {min_db}");
        }
    }

    #[test]
    fn sr_beats_rtn_at_int2_through_the_full_collective() {
        // Table 3's accuracy claim, measured through the complete
        // quantize→pack→transfer→unpack→reduce path.
        let topo = Topology::new(presets::h800(), 8);
        let (rtn, expected) = harness(&topo, 8192, &Codec::parse("int2@32").unwrap(), allreduce);
        let (sr, _) = harness(&topo, 8192, &Codec::parse("int2-sr@32").unwrap(), allreduce);
        let rtn_s = sqnr_db(&expected, &rtn[0]);
        let sr_s = sqnr_db(&expected, &sr[0]);
        assert!(sr_s > rtn_s + 4.0, "SR {sr_s} dB vs RTN {rtn_s} dB");
    }

    #[test]
    fn table5_twostep_cross_numa_volume() {
        // Two-step row of Table 5: cross-NUMA = 4M per direction. The
        // fabric counts both directions (RS + AG), hence 8M measured.
        let topo = Topology::new(presets::l40(), 8);
        let len = 4096usize;
        let inputs: Vec<f32> = (0..len).map(|i| i as f32).collect();
        let ir = &inputs;
        let (_, counters) = run_ranks(&topo, |h| {
            let mut c = Communicator::from_handle(h);
            let mut data = ir.clone();
            allreduce(&mut c, &mut data, &Codec::Bf16).unwrap();
        });
        let m = 2.0 * len as f64; // bf16 bytes per GPU (headers add ~0.4%)
        let total = counters.total_bytes() as f64;
        let cross = counters.cross_numa_bytes() as f64;
        assert!((total / (14.0 * m) - 1.0).abs() < 0.05, "total {total}");
        assert!((cross / (8.0 * m) - 1.0).abs() < 0.05, "cross {cross}");
    }

    #[test]
    fn quantization_cuts_wire_volume() {
        let topo = Topology::new(presets::h800(), 8);
        let len = 8192usize;
        let run = |codec: &Codec| {
            let inputs: Vec<f32> = (0..len).map(|i| (i % 97) as f32).collect();
            let ir = &inputs;
            let (_, counters) = run_ranks(&topo, |h| {
                let mut c = Communicator::from_handle(h);
                let mut data = ir.clone();
                allreduce(&mut c, &mut data, codec).unwrap();
            });
            counters.total_bytes() as f64
        };
        let bf = run(&Codec::Bf16);
        let int5 = run(&Codec::parse("int5").unwrap());
        let int2 = run(&Codec::parse("int2-sr@32!").unwrap());
        // INT5 ≈ 0.33x BF16 on the wire; INT2_SR(int meta) ≈ 0.25x.
        assert!((0.28..0.40).contains(&(int5 / bf)), "int5/bf16 {}", int5 / bf);
        assert!((0.18..0.33).contains(&(int2 / bf)), "int2sr/bf16 {}", int2 / bf);
        assert!(int2 < int5);
    }

    #[test]
    fn reduce_scatter_leaves_other_chunks_untouched() {
        let topo = Topology::new(presets::h800(), 4);
        let len = 100usize;
        let inputs: Vec<Vec<f32>> = (0..4).map(|r| vec![r as f32 + 1.0; len]).collect();
        let ir = &inputs;
        let (results, _) = run_ranks(&topo, |h| {
            let mut c = Communicator::from_handle(h);
            let mut data = ir[c.rank()].clone();
            let own = reduce_scatter(&mut c, &mut data, &Codec::Bf16).unwrap();
            (own, data)
        });
        for (r, (own, data)) in results.iter().enumerate() {
            assert_eq!(*own, chunk_range(len, 4, r));
            for (i, &x) in data.iter().enumerate() {
                if own.contains(&i) {
                    assert!((x - 10.0).abs() < 0.1, "rank {r} elem {i}: reduced {x}");
                } else {
                    assert_eq!(x, r as f32 + 1.0, "rank {r} elem {i}: must stay local");
                }
            }
        }
    }
}
