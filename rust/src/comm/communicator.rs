//! [`Communicator`] — the NCCL-style front door to the collective layer —
//! and [`LocalGroup`], the in-process rank group that drives the same code
//! path for TP shards, DP replicas, and EP dispatch living in one process.
//!
//! A communicator owns, per rank:
//!
//! - the connected transport endpoint (via [`RankHandle`]),
//! - the node [`Topology`] and the job-shared [`ByteCounters`],
//! - persistent codec scratch ([`CodecBuffers`] plus f32 staging buffers),
//!   so repeated collectives are allocation-free after warmup: the first
//!   call sizes the scratch, later calls of the same shape reuse it
//!   (observable via [`Communicator::scratch_bytes`]). The per-message
//!   wire `Vec<u8>` handed to [`Transport::send`] is the one unavoidable
//!   allocation — the transport takes ownership of the payload.
//!
//! # Lifecycle
//!
//! ```text
//! bootstrap transport  ─►  Communicator::new(transport, topo, counters)
//!        │                        │ collectives: allreduce / reduce_scatter /
//!        │                        │ all_gather / broadcast / all2all
//!        ▼                        ▼ every method → Result<_, CommError>
//!   (drop ends membership; counters/topology outlive via Arc/Clone)
//! ```
//!
//! Algorithm choice is per call through an [`AlgoPolicy`]; `Auto` asks the
//! calibrated cost model which algorithm is fastest for this (topology,
//! codec, payload size) — deterministically, so every rank of a job picks
//! the same algorithm without coordination.

use std::mem::size_of;
use std::sync::Arc;

use crate::comm::{
    all2all,
    error::CommError,
    fabric::{ByteCounters, RankHandle},
    hier, pipeline, ring, twostep, Algo, AlgoPolicy,
};
use crate::plan::{self, CommPlan, PlanCache, PlanCacheStats, PlanKey, PlanPolicy};
use crate::quant::{Codec, CodecBuffers};
use crate::record;
use crate::sim::MeasuredProfile;
use crate::telemetry::{self, MetricsRegistry, MetricsSnapshot, Op, Recorder};
use crate::topo::{presets, Topology};
use crate::transport::{inproc, InProcTransport, Transport};

/// One rank's handle to the collective layer. See the module docs.
pub struct Communicator<T: Transport = InProcTransport> {
    pub(crate) handle: RankHandle<T>,
    /// Codec scratch (codes / metas / spikes), reused across calls.
    pub(crate) bufs: CodecBuffers,
    /// f32 staging chunk (ring hops, all-gather self-QDQ).
    pub(crate) scratch: Vec<f32>,
    /// f32 accumulation chunk (one-shot reduce-scatter, hier stages).
    pub(crate) acc: Vec<f32>,
    /// Per-micro-chunk reduced partials (pipelined hierarchical).
    pub(crate) reduced: Vec<Vec<f32>>,
    /// Memoized `Auto` resolution: the cost model (which builds a pipeline
    /// DAG for the hier-pp candidate) is a pure function of
    /// (topology, codec, size), so repeated same-shape calls skip it and
    /// the hot path stays allocation-free after warmup.
    auto_cache: Option<(Codec, usize, Algo)>,
    /// Worker threads the fused codec kernels may use per encode/decode
    /// (chunk parallelism for large payloads). Defaults to 1; see
    /// [`Communicator::set_codec_threads`].
    pub(crate) codec_threads: usize,
    /// Compiled-plan LRU for [`PlanPolicy::Auto`]: keyed by (topology
    /// fingerprint, element count, base codec, pins), so repeated
    /// same-shape calls replay the plan without re-running the search.
    plans: PlanCache,
    /// The plan of the most recent [`allreduce_plan`] call and its stable
    /// fingerprint, memoized so the fingerprint (which formats the plan)
    /// is recomputed only when the plan changes.
    ///
    /// [`allreduce_plan`]: Communicator::allreduce_plan
    last_plan: Option<(CommPlan, u64)>,
    /// Live measurements applied to plan resolution (see
    /// [`Communicator::set_profile`]); `None` prices the static topology.
    profile: Option<MeasuredProfile>,
}

impl<T: Transport> Communicator<T> {
    /// Wrap a connected transport endpoint. `topo` must describe the same
    /// world size the transport was bootstrapped with; `counters` is shared
    /// across every communicator of the same logical job (one per process
    /// for multi-process transports).
    pub fn new(
        transport: T,
        topo: Topology,
        counters: Arc<ByteCounters>,
    ) -> Result<Communicator<T>, CommError> {
        if topo.n_gpus != transport.n() {
            return Err(CommError::shape(format!(
                "topology is {} ranks but the transport mesh has {}",
                topo.n_gpus,
                transport.n()
            )));
        }
        Ok(Communicator::from_handle(RankHandle::new(transport, topo, counters)))
    }

    /// Wrap an existing fabric endpoint (e.g. one handed out by
    /// [`run_ranks`](crate::comm::fabric::run_ranks)).
    pub fn from_handle(handle: RankHandle<T>) -> Communicator<T> {
        Communicator {
            handle,
            bufs: CodecBuffers::default(),
            scratch: Vec::new(),
            acc: Vec::new(),
            reduced: Vec::new(),
            auto_cache: None,
            codec_threads: 1,
            plans: PlanCache::default(),
            last_plan: None,
            profile: None,
        }
    }

    /// Turn the flight recorder on: a fresh per-rank ring holding the
    /// newest `capacity` events (56 bytes each; see
    /// [`crate::telemetry::DEFAULT_CAPACITY`]). The fabric layer starts
    /// recording `Send`/`Recv` spans, the collectives their codec spans,
    /// and [`allreduce_plan`](Communicator::allreduce_plan) the enclosing
    /// `Collective` span. Wire bytes and results are unchanged — recording
    /// observes, it never participates (pinned by tests).
    pub fn enable_recording(&mut self, capacity: usize) {
        self.handle.set_recorder(Some(Arc::new(Recorder::new(self.handle.rank, capacity))));
    }

    /// [`enable_recording`](Communicator::enable_recording) with an
    /// explicit clock origin. Ranks sharing one process pass the same
    /// `Instant` ([`LocalGroup::enable_recording`] does), so their
    /// recorder timelines share a timebase and merge with zero clock
    /// offset by construction; multi-process ranks use
    /// [`crate::session::sync_clocks`] instead.
    pub fn enable_recording_from(&mut self, capacity: usize, origin: std::time::Instant) {
        self.handle.set_recorder(Some(Arc::new(Recorder::with_origin(
            self.handle.rank,
            capacity,
            origin,
        ))));
    }

    /// Turn the flight recorder off and drop its ring.
    pub fn disable_recording(&mut self) {
        self.handle.set_recorder(None);
    }

    /// The flight recorder, when enabled ([`Communicator::enable_recording`]).
    pub fn recorder(&self) -> Option<&Recorder> {
        self.handle.recorder()
    }

    /// This rank's recorded trace as one JSON object (`None` while
    /// recording is disabled). Schema: DESIGN.md §11 /
    /// [`crate::telemetry::trace_json`].
    pub fn trace_json(&self) -> Option<String> {
        self.handle.recorder().map(telemetry::trace_json)
    }

    /// This rank's recorded trace as a typed [`telemetry::RankTrace`]
    /// (`None` while recording is disabled) — the input unit of the
    /// fabric trace merge and critical-path analysis (DESIGN.md §15).
    pub fn rank_trace(&self) -> Option<telemetry::RankTrace> {
        self.handle.recorder().map(telemetry::RankTrace::from_recorder)
    }

    /// Let the fused codec kernels chunk large payloads across up to
    /// `threads` scoped worker threads (quantize+pack and unpack+reduce are
    /// the CPU-bound part of every collective). Wire bytes are identical
    /// for every thread count. Defaults to 1: in-process rank groups
    /// ([`LocalGroup`]) already run one OS thread per rank, so extra codec
    /// threads would oversubscribe the host — raise this only where a rank
    /// owns the process (e.g. `flashcomm worker` with spare cores). Clamped
    /// to `1..=`[`quant::MAX_CODEC_THREADS`](crate::quant::MAX_CODEC_THREADS),
    /// the kernels' hard worker cap.
    pub fn set_codec_threads(&mut self, threads: usize) {
        self.codec_threads = threads.clamp(1, crate::quant::MAX_CODEC_THREADS);
    }

    /// Current codec worker-thread budget (see
    /// [`set_codec_threads`](Communicator::set_codec_threads)).
    pub fn codec_threads(&self) -> usize {
        self.codec_threads
    }

    /// This rank's index in `0..n()`.
    pub fn rank(&self) -> usize {
        self.handle.rank
    }

    /// World size of the job.
    pub fn n(&self) -> usize {
        self.handle.n
    }

    /// The node topology this communicator models.
    pub fn topo(&self) -> &Topology {
        self.handle.topo()
    }

    /// Shared byte counters (same instance across all ranks of this job).
    pub fn counters(&self) -> &ByteCounters {
        self.handle.counters()
    }

    /// The underlying transport endpoint (e.g. for
    /// [`Transport::stats`](crate::transport::Transport::stats)).
    pub fn transport(&self) -> &T {
        self.handle.transport()
    }

    /// The raw fabric endpoint (point-to-point send/recv).
    pub fn handle(&self) -> &RankHandle<T> {
        &self.handle
    }

    /// In-place AllReduce of `data` across all ranks: every rank ends with
    /// a bit-identical wire-precision image of the element-wise sum.
    /// Returns the algorithm the policy resolved to.
    ///
    /// This is the [`AlgoPolicy`] shim over the plan layer: the resolved
    /// algorithm becomes a *uniform* [`CommPlan`] (one codec everywhere,
    /// default chunk count and send window) executed by
    /// [`allreduce_plan`](Communicator::allreduce_plan). Use
    /// [`allreduce_planned`](Communicator::allreduce_planned) for
    /// mixed-stage plans or cost-model-tuned knobs.
    pub fn allreduce(
        &mut self,
        data: &mut [f32],
        codec: &Codec,
        policy: AlgoPolicy,
    ) -> Result<Algo, CommError> {
        let algo = match (policy, self.auto_cache) {
            (AlgoPolicy::Fixed(a), _) => a,
            (AlgoPolicy::Auto, Some((c, len, a))) if c == *codec && len == data.len() => a,
            (AlgoPolicy::Auto, _) => {
                let a = policy.resolve(&self.effective_topo(), codec, data.len());
                self.auto_cache = Some((*codec, data.len(), a));
                a
            }
        };
        self.allreduce_plan(data, &CommPlan::uniform(algo, *codec))?;
        Ok(algo)
    }

    /// In-place AllReduce running exactly `plan` — the execution half of
    /// the plan layer. Validates the plan against this communicator's
    /// topology first, so an inadmissible or malformed plan is a typed
    /// error before any byte moves. A plan `codec_threads` of 0 inherits
    /// this communicator's [`codec_threads`](Communicator::codec_threads);
    /// a nonzero value overrides it for this call only.
    pub fn allreduce_plan(&mut self, data: &mut [f32], plan: &CommPlan) -> Result<(), CommError> {
        plan.validate(self.topo())?;
        let fp = self.note_plan(plan);
        if let Some(rec) = self.handle.recorder() {
            rec.set_plan(fp, telemetry::algo_tag(plan.algo));
        }
        record!(self.handle.recorder(), start Op::Collective, data.len() as u64);
        let result = self.with_plan_threads(plan, |c| match plan.algo {
            Algo::Ring => ring::allreduce(c, data, &plan.stage_codecs.intra_rs),
            Algo::TwoStep => twostep::allreduce(c, data, &plan.stage_codecs.intra_rs),
            Algo::Hier => hier::allreduce_staged(c, data, &plan.stage_codecs),
            Algo::HierPipelined => pipeline::allreduce_planned(
                c,
                data,
                &plan.stage_codecs,
                plan.chunks,
                plan.send_window,
            ),
        });
        if let Some(rec) = self.handle.recorder() {
            // Close on a clean frame so the End pairs with the Start
            // regardless of the stage context the algorithm left behind.
            rec.set_plan(fp, telemetry::algo_tag(plan.algo));
            rec.record(crate::telemetry::Kind::End, Op::Collective, 0);
        }
        result
    }

    /// Memoize the plan about to run and return its stable fingerprint
    /// (recomputed only when the plan changes — fingerprinting formats
    /// the plan, which the hot path should not repeat per call).
    fn note_plan(&mut self, plan: &CommPlan) -> u64 {
        match &self.last_plan {
            Some((p, fp)) if p == plan => *fp,
            _ => {
                let fp = plan.fingerprint();
                self.last_plan = Some((*plan, fp));
                fp
            }
        }
    }

    /// The resolved plan and stable fingerprint of the most recent
    /// [`allreduce_plan`](Communicator::allreduce_plan) call (every
    /// allreduce entry point funnels through it).
    pub fn last_plan(&self) -> Option<&(CommPlan, u64)> {
        self.last_plan.as_ref()
    }

    /// In-place AllReduce under a [`PlanPolicy`]: `Fixed` runs its plan
    /// verbatim, `Auto` compiles one for (this topology, `data.len()`,
    /// `codec`) through the plan cache — so a warmed-up hot path replays
    /// the compiled plan with zero search work (observable via
    /// [`plan_cache_stats`](Communicator::plan_cache_stats)). Returns the
    /// plan that ran. Deterministic: every rank of a job resolves the
    /// same plan without coordination.
    pub fn allreduce_planned(
        &mut self,
        data: &mut [f32],
        codec: &Codec,
        policy: &PlanPolicy,
    ) -> Result<CommPlan, CommError> {
        let plan = self.resolve_plan(codec, data.len(), policy)?;
        self.allreduce_plan(data, &plan)?;
        Ok(plan)
    }

    /// The plan `policy` runs for `elems` f32 values of `codec` on this
    /// communicator's topology (the resolution half of
    /// [`allreduce_planned`](Communicator::allreduce_planned), split out
    /// for harnesses that want to inspect or log the pick). `Auto` prices
    /// candidates against the [effective](Communicator::effective_topo)
    /// topology — the static calibration corrected by any installed
    /// [`MeasuredProfile`] — and the recalibrated fingerprint keys the
    /// plan cache, so profiled and unprofiled resolutions never collide.
    pub fn resolve_plan(
        &mut self,
        codec: &Codec,
        elems: usize,
        policy: &PlanPolicy,
    ) -> Result<CommPlan, CommError> {
        match policy {
            PlanPolicy::Fixed(p) => Ok(*p),
            PlanPolicy::Auto(pins) => {
                pins.validate().map_err(|e| CommError::shape(format!("{e:#}")))?;
                let topo = self.effective_topo();
                let key = PlanKey::new(&topo, elems, codec, *pins);
                Ok(self
                    .plans
                    .get_or_insert_with(key, || plan::compile_pinned(&topo, elems, codec, *pins)))
            }
        }
    }

    /// Install live measurements for plan resolution: every sane term of
    /// `profile` overrides the static calibration's priced rate (see
    /// [`MeasuredProfile::apply`]). An empty profile clears back to the
    /// static topology. Invalidates the memoized `Auto` algorithm pick;
    /// compiled plans stay cached under their (distinct) recalibrated
    /// topology fingerprint.
    pub fn set_profile(&mut self, profile: MeasuredProfile) {
        self.profile = (!profile.is_empty()).then_some(profile);
        self.auto_cache = None;
    }

    /// The installed measurement profile, if any.
    pub fn profile(&self) -> Option<&MeasuredProfile> {
        self.profile.as_ref()
    }

    /// Distill a [`MeasuredProfile`] from this rank's recorded trace
    /// ([`crate::telemetry::distill_profile`]) and install it for
    /// subsequent plan resolution. Returns the profile when anything was
    /// measurable; `None` (installing nothing) when recording is off or
    /// the trace has no completed spans.
    pub fn recalibrate_from_recorder(&mut self) -> Option<MeasuredProfile> {
        let events = self.handle.recorder()?.events();
        let profile = telemetry::distill_profile(&events);
        if profile.is_empty() {
            return None;
        }
        self.set_profile(profile);
        Some(profile)
    }

    /// The topology plan resolution prices against: the static topology,
    /// recalibrated by the installed profile when one is set.
    pub fn effective_topo(&self) -> Topology {
        match &self.profile {
            Some(p) => p.apply(self.handle.topo()),
            None => self.handle.topo().clone(),
        }
    }

    /// Everything this rank measures, absorbed into one
    /// [`MetricsRegistry`]: recorded span series, the fabric byte
    /// counters, transport counters, plan-cache counters, and the last
    /// resolved plan.
    pub fn metrics_registry(&self) -> MetricsRegistry {
        let mut reg = MetricsRegistry::new();
        if let Some(rec) = self.handle.recorder() {
            reg.absorb_events(&rec.events());
            reg.absorb_recorder(rec);
        }
        reg.absorb_fabric(self.counters().snapshot());
        reg.absorb_transport(self.transport().stats());
        if let Some(session) = self.transport().session_stats() {
            reg.absorb_session(session);
        }
        reg.absorb_plan_cache(self.plans.stats());
        if let Some((plan, fp)) = &self.last_plan {
            reg.set_last_plan(plan.to_string(), *fp);
        }
        reg
    }

    /// Continue over the surviving membership after the session fabric
    /// declared `lost` ranks dead: the transport is rewrapped in a
    /// [`crate::session::DegradedMesh`] (dense renumbering over the
    /// survivors, per-link seq spaces intact) and the topology replaced by
    /// [`crate::session::survivor_topology`] — whose changed fingerprint
    /// guarantees the
    /// plan compiler never replays a full-membership plan against the
    /// shrunk mesh. Scratch, plan cache, and the flight recorder start
    /// fresh (shapes, fingerprints, and the rank id all change); the
    /// job-shared byte counters carry across the loss.
    pub fn into_degraded(
        self,
        lost: &[usize],
    ) -> Result<Communicator<crate::session::DegradedMesh<T>>, CommError> {
        let (transport, topo, counters) = self.handle.into_parts();
        let survivors = crate::session::survivor_topology(&topo, lost)?;
        let mesh = crate::session::DegradedMesh::new(transport, lost)?;
        Communicator::new(mesh, survivors, counters)
    }

    /// [`metrics_registry`](Communicator::metrics_registry), materialized.
    pub fn metrics_snapshot(&self) -> MetricsSnapshot {
        self.metrics_registry().snapshot()
    }

    /// Hit/miss/eviction counters of this communicator's compiled-plan
    /// cache (hits mean the hot path skipped the plan search entirely).
    pub fn plan_cache_stats(&self) -> PlanCacheStats {
        self.plans.stats()
    }

    /// Pipelined hierarchical AllReduce with an explicit micro-chunk count
    /// (the Fig. 8 knob; [`Algo::HierPipelined`] uses the default).
    pub fn allreduce_chunked(
        &mut self,
        data: &mut [f32],
        codec: &Codec,
        chunks: usize,
    ) -> Result<(), CommError> {
        pipeline::allreduce_chunked(self, data, codec, chunks)
    }

    /// One-shot reduce-scatter: after the call, `data[range]` (the returned
    /// range — this rank's balanced chunk) holds the sum of every rank's
    /// values for that chunk; the rest of `data` is untouched.
    pub fn reduce_scatter(
        &mut self,
        data: &mut [f32],
        codec: &Codec,
    ) -> Result<std::ops::Range<usize>, CommError> {
        twostep::reduce_scatter(self, data, codec)
    }

    /// One-shot all-gather of each rank's owned chunk (the complement of
    /// [`reduce_scatter`](Communicator::reduce_scatter)): every rank ends
    /// with the full, bit-identical vector. The own chunk takes one QDQ so
    /// ranks agree bitwise.
    pub fn all_gather(&mut self, data: &mut [f32], codec: &Codec) -> Result<(), CommError> {
        twostep::all_gather(self, data, codec)
    }

    /// Broadcast `root`'s `data` to every rank through the wire codec.
    /// All ranks (including the root, via a self-QDQ) end bit-identical.
    pub fn broadcast(
        &mut self,
        data: &mut [f32],
        root: usize,
        codec: &Codec,
    ) -> Result<(), CommError> {
        twostep::broadcast(self, data, root, codec)
    }

    /// Exchange `sends[d]` with every rank `d`, quantizing with `codec`.
    /// Returns `recv[s]` — the decoded payload rank `s` sent us. Payload
    /// sizes may differ per destination (MoE routing is never balanced);
    /// the self payload takes the same QDQ as remote ones.
    pub fn all2all(
        &mut self,
        sends: &[Vec<f32>],
        codec: &Codec,
    ) -> Result<Vec<Vec<f32>>, CommError> {
        all2all::all2all(self, sends, codec)
    }

    /// [`reduce_scatter`](Communicator::reduce_scatter) under a plan: the
    /// plan supplies the (uniform) codec and the thread budget. All five
    /// collectives accept plans; the one-stage ones require
    /// [`CommPlan::uniform_codec`].
    pub fn reduce_scatter_planned(
        &mut self,
        data: &mut [f32],
        plan: &CommPlan,
    ) -> Result<std::ops::Range<usize>, CommError> {
        let codec = self.plan_codec(plan)?;
        self.with_plan_threads(plan, |c| twostep::reduce_scatter(c, data, &codec))
    }

    /// [`all_gather`](Communicator::all_gather) under a plan.
    pub fn all_gather_planned(
        &mut self,
        data: &mut [f32],
        plan: &CommPlan,
    ) -> Result<(), CommError> {
        let codec = self.plan_codec(plan)?;
        self.with_plan_threads(plan, |c| twostep::all_gather(c, data, &codec))
    }

    /// [`broadcast`](Communicator::broadcast) under a plan.
    pub fn broadcast_planned(
        &mut self,
        data: &mut [f32],
        root: usize,
        plan: &CommPlan,
    ) -> Result<(), CommError> {
        let codec = self.plan_codec(plan)?;
        self.with_plan_threads(plan, |c| twostep::broadcast(c, data, root, &codec))
    }

    /// [`all2all`](Communicator::all2all) under a plan.
    pub fn all2all_planned(
        &mut self,
        sends: &[Vec<f32>],
        plan: &CommPlan,
    ) -> Result<Vec<Vec<f32>>, CommError> {
        let codec = self.plan_codec(plan)?;
        self.with_plan_threads(plan, |c| all2all::all2all(c, sends, &codec))
    }

    /// The uniform codec a one-stage collective runs for `plan`, as a
    /// typed [`CommError::Shape`] on mixed-stage plans.
    fn plan_codec(&self, plan: &CommPlan) -> Result<Codec, CommError> {
        plan.stage_codecs
            .validate()
            .and_then(|()| plan.uniform_codec())
            .map_err(|e| CommError::shape(format!("{e:#}")))
    }

    /// Run `f` with the plan's thread override applied (0 = inherit).
    fn with_plan_threads<R>(
        &mut self,
        plan: &CommPlan,
        f: impl FnOnce(&mut Communicator<T>) -> Result<R, CommError>,
    ) -> Result<R, CommError> {
        let inherited = self.codec_threads;
        if plan.codec_threads != 0 {
            self.set_codec_threads(plan.codec_threads);
        }
        let result = f(self);
        self.codec_threads = inherited;
        result
    }

    /// Bytes of owned scratch currently held (codec buffers + f32 staging).
    /// Stable across repeated same-shape collectives after the first call —
    /// the hot path reuses rather than reallocates (asserted in tests).
    pub fn scratch_bytes(&self) -> usize {
        self.bufs.capacity_bytes()
            + 4 * (self.scratch.capacity() + self.acc.capacity())
            + self.reduced.capacity() * size_of::<Vec<f32>>()
            + self.reduced.iter().map(|v| 4 * v.capacity()).sum::<usize>()
    }
}

/// The device preset an in-process rank group (TP shards, DP replicas)
/// models for a given policy: the NUMA (L40) node when the policy wants —
/// or may want — the hierarchical algorithms and the rank count supports
/// two equal groups, the flat NVLink (H800) node otherwise.
pub fn preset_topo(n: usize, policy: AlgoPolicy) -> Result<Topology, CommError> {
    preset_topo_grouped(n, None, policy)
}

/// [`preset_topo`] with an explicit link-tier group count (the CLI's
/// `--groups`): `Some(1)` forces the flat NVLink node, `Some(G >= 2)` a
/// G-group NUMA (L40-bridge) box, `None` the policy-driven default. The
/// returned topology is validated against a fixed policy's admissibility
/// (`Algo::admissible` — the one source of truth), so e.g.
/// `--groups 1 --algo hier` fails here, once, instead of in every rank.
pub fn preset_topo_grouped(
    n: usize,
    groups: Option<usize>,
    policy: AlgoPolicy,
) -> Result<Topology, CommError> {
    if n < 2 {
        return Err(CommError::shape(format!("a rank group needs at least 2 ranks, got {n}")));
    }
    let topo = match groups {
        Some(g) if g >= 2 => Topology::try_with_groups(presets::l40(), n, g)?,
        // g == 1 is the flat node; g == 0 propagates as TopologyError::
        // ZeroGroups — never silently coerced to a shape the user didn't ask for.
        Some(g) => Topology::try_with_groups(presets::h800(), n, g)?,
        None => {
            let two_groups_ok = n % 2 == 0;
            let numa = match policy {
                AlgoPolicy::Fixed(Algo::Hier | Algo::HierPipelined) => true,
                AlgoPolicy::Auto => two_groups_ok,
                AlgoPolicy::Fixed(_) => false,
            };
            if numa {
                Topology::try_with_groups(presets::l40(), n, 2)?
            } else {
                Topology::try_with_groups(presets::h800(), n, 1)?
            }
        }
    };
    if let AlgoPolicy::Fixed(a) = policy {
        a.admissible(&topo)?;
    }
    Ok(topo)
}

/// [`preset_topo_grouped`] with an optional effective inter-group
/// bandwidth override in GB/s (the CLI's `--inter-gbps`). With an
/// override, the preset models a *multi-node NVLink cluster*: `G >= 2`
/// flat NVLink (H800-class) groups joined by a link of the given
/// effective bandwidth — the generalized
/// [`presets::dual_nvlink_node`] shape at any admissible `G`, and the
/// regime where the plan compiler's tier-asymmetry gate admits
/// mixed-stage plans. Without one it is exactly [`preset_topo_grouped`].
pub fn preset_topo_custom(
    n: usize,
    groups: Option<usize>,
    inter_gbps: Option<f64>,
    policy: AlgoPolicy,
) -> Result<Topology, CommError> {
    let Some(gbps) = inter_gbps else {
        return preset_topo_grouped(n, groups, policy);
    };
    if !(gbps > 0.0 && gbps.is_finite()) {
        return Err(CommError::shape(format!(
            "--inter-gbps must be a positive bandwidth, got {gbps}"
        )));
    }
    let g = groups.unwrap_or(2);
    if g < 2 {
        return Err(CommError::shape(format!(
            "an inter-group link needs >= 2 groups (--inter-gbps with --groups {g})"
        )));
    }
    let topo = Topology::try_custom(presets::h800(), n, g, Some(gbps * 1e9))?;
    if let AlgoPolicy::Fixed(a) = policy {
        a.admissible(&topo)?;
    }
    Ok(topo)
}

/// An in-process rank group: `n` communicators over a private mpsc mesh,
/// one OS thread per rank per collective call. This is how single-process
/// engines (TP inference, the DP trainer, EP boundaries) run their partial
/// sums through the *same* Communicator code path — and therefore the same
/// QDQ chain — as the multi-process fabric, instead of a hand-rolled
/// second implementation.
///
/// The [`AlgoPolicy`] is fixed at construction: the group's preset
/// topology is chosen *for* that policy, so letting callers pass a
/// different one per call could silently strand `Auto` on a topology
/// that cannot host the hierarchical family. Build a new group to change
/// policy.
pub struct LocalGroup {
    comms: Vec<Communicator<InProcTransport>>,
    policy: AlgoPolicy,
    /// When set, allreduce calls run through the plan layer with this
    /// policy instead of the (shim) `AlgoPolicy` — the CLI's `--plan`.
    plan: Option<PlanPolicy>,
}

impl LocalGroup {
    /// Build a group over an explicit topology, running `policy`.
    pub fn new(topo: &Topology, policy: AlgoPolicy) -> Result<LocalGroup, CommError> {
        let counters = Arc::new(ByteCounters::default());
        let comms = inproc::mesh(topo.n_gpus)
            .into_iter()
            .map(|t| Communicator::new(t, topo.clone(), counters.clone()))
            .collect::<Result<Vec<_>, CommError>>()?;
        Ok(LocalGroup { comms, policy, plan: None })
    }

    /// Build a group over an explicit topology, running a [`PlanPolicy`]:
    /// a `Fixed` plan is validated against `topo` once, up front; `Auto`
    /// compiles per payload shape through each rank's plan cache (every
    /// rank resolves the same plan — the compiler is deterministic).
    pub fn new_planned(topo: &Topology, policy: PlanPolicy) -> Result<LocalGroup, CommError> {
        if let PlanPolicy::Fixed(p) = &policy {
            p.validate(topo)?;
        }
        let mut group = LocalGroup::new(topo, policy.algo_hint())?;
        group.plan = Some(policy);
        Ok(group)
    }

    /// [`LocalGroup::new_planned`] over the preset topology for the
    /// policy's algorithm hint (see [`preset_topo_grouped`]).
    pub fn for_plan_grouped(
        n: usize,
        groups: Option<usize>,
        policy: PlanPolicy,
    ) -> Result<LocalGroup, CommError> {
        LocalGroup::new_planned(&preset_topo_grouped(n, groups, policy.algo_hint())?, policy)
    }

    /// Build a group of `n` ranks over the [`preset_topo`] for `policy`.
    pub fn for_policy(n: usize, policy: AlgoPolicy) -> Result<LocalGroup, CommError> {
        LocalGroup::new(&preset_topo(n, policy)?, policy)
    }

    /// [`LocalGroup::for_policy`] with an explicit link-tier group count
    /// (the CLI's `--groups`; see [`preset_topo_grouped`]).
    pub fn for_policy_grouped(
        n: usize,
        groups: Option<usize>,
        policy: AlgoPolicy,
    ) -> Result<LocalGroup, CommError> {
        LocalGroup::new(&preset_topo_grouped(n, groups, policy)?, policy)
    }

    pub fn n(&self) -> usize {
        self.comms.len()
    }

    pub fn topo(&self) -> &Topology {
        self.comms[0].topo()
    }

    /// The policy this group was built for.
    pub fn policy(&self) -> AlgoPolicy {
        self.policy
    }

    /// The plan policy this group runs, when built through the plan layer
    /// ([`LocalGroup::new_planned`] / [`LocalGroup::for_plan_grouped`]).
    pub fn plan_policy(&self) -> Option<&PlanPolicy> {
        self.plan.as_ref()
    }

    /// Aggregate compiled-plan cache counters across the group's ranks
    /// (all zeros unless the group runs a [`PlanPolicy`]).
    pub fn plan_cache_stats(&self) -> PlanCacheStats {
        self.comms.iter().map(|c| c.plan_cache_stats()).fold(
            PlanCacheStats::default(),
            |a, b| PlanCacheStats {
                hits: a.hits + b.hits,
                misses: a.misses + b.misses,
                evictions: a.evictions + b.evictions,
            },
        )
    }

    /// The group-shared byte counters (payload volume accounting).
    pub fn counters(&self) -> &ByteCounters {
        self.comms[0].counters()
    }

    /// Turn the flight recorder on for every rank, all sharing **one**
    /// clock origin ([`Communicator::enable_recording_from`]): in-process
    /// ranks live in one address space, so their merged fabric trace
    /// needs no probe exchange — the clock offsets are zero by
    /// construction.
    pub fn enable_recording(&mut self, capacity: usize) {
        let origin = std::time::Instant::now();
        for c in &mut self.comms {
            c.enable_recording_from(capacity, origin);
        }
    }

    /// Per-rank communicators, rank order (read-only observability view).
    pub fn ranks(&self) -> &[Communicator<InProcTransport>] {
        &self.comms
    }

    /// Per-rank trace JSON, in rank order (empty while recording is off).
    pub fn trace_jsons(&self) -> Vec<String> {
        self.comms.iter().filter_map(Communicator::trace_json).collect()
    }

    /// Per-rank typed traces, in rank order (empty while recording is
    /// off) — ready for [`telemetry::merge_traces`] /
    /// [`telemetry::analyze`].
    pub fn rank_traces(&self) -> Vec<telemetry::RankTrace> {
        self.comms.iter().filter_map(Communicator::rank_trace).collect()
    }

    /// Critical-path and straggler analysis over the group's merged
    /// timeline ([`telemetry::analyze`]; empty report while recording is
    /// off).
    pub fn fabric_report(&self) -> telemetry::FabricReport {
        telemetry::analyze(&self.rank_traces())
    }

    /// Group-wide metrics: every rank's recorded spans, plan-cache
    /// counters, transport counters, and last resolved plan folded into
    /// one registry, plus the (group-shared) fabric counters absorbed
    /// once.
    pub fn metrics_snapshot(&self) -> MetricsSnapshot {
        let mut reg = MetricsRegistry::new();
        for c in &self.comms {
            if let Some(rec) = c.recorder() {
                reg.absorb_events(&rec.events());
                reg.absorb_recorder(rec);
            }
            reg.absorb_transport(c.transport().stats());
            reg.absorb_plan_cache(c.plan_cache_stats());
            if let Some((plan, fp)) = c.last_plan() {
                reg.set_last_plan(plan.to_string(), *fp);
            }
        }
        reg.absorb_fabric(self.counters().snapshot());
        reg.absorb_stragglers(&self.fabric_report().stragglers);
        reg.snapshot()
    }

    /// Distill one [`MeasuredProfile`] from the group's merged fabric
    /// timeline ([`telemetry::distill_fabric_profile`]: the median of
    /// per-span rates across every rank, robust to a straggler that a
    /// pooled per-rank distillation would average into the bandwidth
    /// estimate) and install it on every rank, so subsequent
    /// `--plan auto` resolution prices the fabric critical path. `None`
    /// (and no change) when nothing measurable was recorded.
    pub fn recalibrate_from_recorders(&mut self) -> Option<MeasuredProfile> {
        let profile = telemetry::distill_fabric_profile(&self.rank_traces());
        if profile.is_empty() {
            return None;
        }
        for c in &mut self.comms {
            c.set_profile(profile);
        }
        Some(profile)
    }

    /// AllReduce `per_rank[r]` as rank `r`'s contribution, in place: after
    /// the call every entry holds the same wire-precision sum. One scoped
    /// OS thread per rank; scratch stays warm across calls.
    pub fn allreduce(
        &mut self,
        per_rank: &mut [Vec<f32>],
        codec: &Codec,
    ) -> Result<Algo, CommError> {
        if per_rank.len() != self.comms.len() {
            return Err(CommError::shape(format!(
                "{} payloads for a {}-rank group",
                per_rank.len(),
                self.comms.len()
            )));
        }
        let len0 = per_rank[0].len();
        if per_rank.iter().any(|v| v.len() != len0) {
            return Err(CommError::shape("per-rank payload lengths differ".to_string()));
        }
        let policy = self.policy;
        let plan = self.plan;
        let results: Vec<Result<Algo, CommError>> = std::thread::scope(|scope| {
            let joins: Vec<_> = self
                .comms
                .iter_mut()
                .zip(per_rank.iter_mut())
                .map(|(c, d)| {
                    scope.spawn(move || match plan {
                        Some(pp) => c.allreduce_planned(d, codec, &pp).map(|p| p.algo),
                        None => c.allreduce(d, codec, policy),
                    })
                })
                .collect();
            // lint: allow(panic, "a panicked rank thread is a programming error; propagate it")
            joins.into_iter().map(|j| j.join().expect("rank panicked")).collect()
        });
        let mut algo = None;
        for r in results {
            algo = Some(r?);
        }
        // lint: allow(panic, "Topology starts at 2 GPUs, so the loop above ran at least twice")
        Ok(algo.expect("group has at least 2 ranks"))
    }

    /// Total owned scratch across the group's communicators.
    pub fn scratch_bytes(&self) -> usize {
        self.comms.iter().map(Communicator::scratch_bytes).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::fabric::run_ranks;
    use crate::util::stats::sqnr_db;
    use crate::util::Prng;

    fn codec(s: &str) -> Codec {
        Codec::parse(s).unwrap()
    }

    fn per_rank_data(n: usize, len: usize) -> Vec<Vec<f32>> {
        (0..n)
            .map(|r| {
                let mut rng = Prng::new(7100 + r as u64);
                let mut v = vec![0f32; len];
                rng.fill_activations(&mut v, 1.0);
                v
            })
            .collect()
    }

    const MB: usize = 1024 * 1024;

    #[test]
    fn auto_picks_hier_above_crossover_on_l40() {
        // Acceptance pin: above the cost-model crossover the NUMA node runs
        // the hierarchical family; far below it, the one-shot two-step.
        let topo = Topology::new(presets::l40(), 8);
        let c = codec("int4@32");
        let large = AlgoPolicy::Auto.resolve(&topo, &c, 32 * MB); // 64 MiB payload
        assert!(
            matches!(large, Algo::Hier | Algo::HierPipelined),
            "L40 large: {large:?}"
        );
        let small = AlgoPolicy::Auto.resolve(&topo, &c, 8 * 1024); // 16 KiB payload
        assert_eq!(small, Algo::TwoStep, "L40 small");
    }

    #[test]
    fn auto_stays_one_shot_on_h800() {
        // No NUMA bridge on NVLink nodes: the hierarchical family is never
        // admissible; the quantized ring never is (error compounds).
        let topo = Topology::new(presets::h800(), 8);
        let c = codec("int4@32");
        for elems in [4 * 1024usize, 32 * MB] {
            assert_eq!(AlgoPolicy::Auto.resolve(&topo, &c, elems), Algo::TwoStep);
        }
    }

    #[test]
    fn auto_bf16_regimes_on_l40() {
        // BF16 keeps the ring admissible (no error compounding without a
        // lossy codec). Large payloads: the two-step is dominated by its 4M
        // cross-NUMA volume, leaving the ring or the pipelined hierarchy.
        // Small payloads: the ring's 2(N−1) launch latencies lose to the
        // two-step's 2.
        let topo = Topology::new(presets::l40(), 8);
        let large = AlgoPolicy::Auto.resolve(&topo, &Codec::Bf16, 32 * MB);
        assert!(matches!(large, Algo::Ring | Algo::HierPipelined), "L40 bf16 large: {large:?}");
        let small = AlgoPolicy::Auto.resolve(&topo, &Codec::Bf16, 8 * 1024);
        assert_eq!(small, Algo::TwoStep, "L40 bf16 small");
    }

    #[test]
    fn auto_is_deterministic() {
        let l40 = Topology::new(presets::l40(), 8);
        let h800 = Topology::new(presets::h800(), 8);
        for c in ["bf16", "int8", "int4@32", "int2-sr@32!"] {
            let c = codec(c);
            for elems in [1usize, 4096, 500_000, 32 * MB] {
                for topo in [&l40, &h800] {
                    let first = AlgoPolicy::Auto.resolve(topo, &c, elems);
                    for _ in 0..20 {
                        assert_eq!(
                            AlgoPolicy::Auto.resolve(topo, &c, elems),
                            first,
                            "(topology, codec, size) must map to one algorithm"
                        );
                    }
                    // A fresh, identical topology resolves identically.
                    assert_eq!(AlgoPolicy::Auto.resolve(&topo.clone(), &c, elems), first);
                }
            }
        }
    }

    #[test]
    fn local_group_matches_fabric_collective_bitwise() {
        // The unified QDQ path: a LocalGroup allreduce must be bit-identical
        // to the same collective over run_ranks handles.
        let topo = Topology::new(presets::l40(), 4);
        let c = codec("int2-sr@32!");
        let data = per_rank_data(4, 1536);

        let mut group = LocalGroup::new(&topo, AlgoPolicy::Fixed(Algo::Hier)).unwrap();
        let mut mine = data.clone();
        group.allreduce(&mut mine, &c).unwrap();

        let dref = &data;
        let (fabric_r, _) = run_ranks(&topo, |h| {
            let mut comm = Communicator::from_handle(h);
            let mut d = dref[comm.rank()].clone();
            comm.allreduce(&mut d, &c, AlgoPolicy::Fixed(Algo::Hier)).unwrap();
            d
        });
        for r in 0..4 {
            let a: Vec<u32> = mine[r].iter().map(|x| x.to_bits()).collect();
            let b: Vec<u32> = fabric_r[r].iter().map(|x| x.to_bits()).collect();
            assert_eq!(a, b, "rank {r}");
        }
    }

    #[test]
    fn hot_path_is_allocation_free_after_warmup() {
        // Acceptance pin: repeated allreduce calls reuse owned scratch — the
        // scratch byte counter must not grow after the first call.
        for policy in [
            AlgoPolicy::Fixed(Algo::TwoStep),
            AlgoPolicy::Fixed(Algo::Ring),
            AlgoPolicy::Fixed(Algo::Hier),
            AlgoPolicy::Fixed(Algo::HierPipelined),
        ] {
            let mut group = LocalGroup::for_policy(4, policy).unwrap();
            let c = codec("int2-sr@32!");
            let mut data = per_rank_data(4, 4096);
            group.allreduce(&mut data, &c).unwrap();
            let warm = group.scratch_bytes();
            assert!(warm > 0, "{policy}: warmup must size the scratch");
            for _ in 0..4 {
                let mut data = per_rank_data(4, 4096);
                group.allreduce(&mut data, &c).unwrap();
                assert_eq!(
                    group.scratch_bytes(),
                    warm,
                    "{policy}: hot path must reuse scratch, not grow it"
                );
            }
        }
    }

    #[test]
    fn recording_surfaces_metrics_traces_and_plan_fingerprints() {
        let mut group =
            LocalGroup::for_plan_grouped(4, Some(2), crate::plan::PlanPolicy::auto()).unwrap();
        group.enable_recording(4096);
        let c = codec("int4@32");
        for _ in 0..2 {
            let mut data = per_rank_data(4, 8192);
            group.allreduce(&mut data, &c).unwrap();
        }
        // Every rank resolved and ran the identical plan: fingerprints
        // agree (the distributed-consistency check `flashcomm worker`
        // runs over TCP, exercised here in-process).
        let fps: Vec<u64> =
            group.ranks().iter().map(|r| r.last_plan().expect("plan ran").1).collect();
        assert!(fps.iter().all(|f| *f == fps[0]), "{fps:?}");
        // Traces: one JSON per rank, each carrying the collective span.
        let traces = group.trace_jsons();
        assert_eq!(traces.len(), 4);
        for t in &traces {
            assert!(t.contains("\"events\":[{"), "rank trace must be non-empty: {t}");
            assert!(t.contains("\"op\":\"collective\""), "{t}");
        }
        // The aggregated snapshot carries every source.
        let snap = group.metrics_snapshot();
        let collective = snap
            .series
            .iter()
            .find(|(k, _)| k.op == crate::telemetry::Op::Collective)
            .expect("collective series");
        assert_eq!(collective.1.spans, 8, "2 calls x 4 ranks");
        assert_eq!(snap.unpaired, 0, "nothing wrapped at this capacity");
        assert!(snap.fabric.unwrap().total > 0);
        assert_eq!(snap.plan_cache.unwrap().misses, 4, "one compile per rank");
        assert_eq!(snap.plan_cache.unwrap().hits, 4, "the second call replays");
        assert!(snap.last_plan.is_some());
        // Live recalibration distills a usable profile and keeps the
        // group functional (profiled plans are re-keyed, not clobbered).
        let profile = group.recalibrate_from_recorders().expect("measurable spans");
        assert!(profile.intra_bw.is_some(), "{profile:?}");
        for r in group.ranks() {
            assert_eq!(r.profile(), Some(&profile));
        }
        let mut data = per_rank_data(4, 8192);
        group.allreduce(&mut data, &c).unwrap();
        for r in &data {
            assert_eq!(r, &data[0], "ranks must still agree after recalibration");
        }
    }

    #[test]
    fn recording_is_off_by_default_and_metrics_still_export() {
        let mut group = LocalGroup::for_policy(4, AlgoPolicy::Auto).unwrap();
        let mut data = per_rank_data(4, 512);
        group.allreduce(&mut data, &Codec::Bf16).unwrap();
        for c in group.ranks() {
            assert!(c.recorder().is_none(), "recording must be opt-in");
            assert!(c.trace_json().is_none());
        }
        let snap = group.metrics_snapshot();
        assert!(snap.series.is_empty(), "no recorder, no span series");
        assert!(snap.fabric.unwrap().total > 0, "fabric counters still flow");
        let json = snap.to_json();
        assert!(json.contains("\"fabric\""), "{json}");
    }

    #[test]
    fn fixed_hier_errors_cleanly_on_flat_topology() {
        let topo = Topology::new(presets::h800(), 4);
        let mut group = LocalGroup::new(&topo, AlgoPolicy::Fixed(Algo::Hier)).unwrap();
        let mut data = per_rank_data(4, 64);
        let err = group.allreduce(&mut data, &Codec::Bf16).unwrap_err();
        assert!(matches!(err, CommError::Topology { algo: Algo::Hier, .. }), "{err}");
    }

    #[test]
    fn algo_policy_shim_is_bit_identical_to_explicit_uniform_plans() {
        // The AlgoPolicy arms are now sugar over uniform CommPlans; both
        // entry points must produce the same bits.
        let topo = Topology::new(presets::l40(), 8);
        let c = codec("int2-sr@32!");
        let data = per_rank_data(8, 1536);
        for algo in [Algo::Ring, Algo::TwoStep, Algo::Hier, Algo::HierPipelined] {
            let dref = &data;
            let (shim, _) = run_ranks(&topo, |h| {
                let mut comm = Communicator::from_handle(h);
                let mut d = dref[comm.rank()].clone();
                comm.allreduce(&mut d, &c, AlgoPolicy::Fixed(algo)).unwrap();
                d
            });
            let (planned, _) = run_ranks(&topo, |h| {
                let mut comm = Communicator::from_handle(h);
                let mut d = dref[comm.rank()].clone();
                comm.allreduce_plan(&mut d, &crate::plan::CommPlan::uniform(algo, c)).unwrap();
                d
            });
            for r in 0..8 {
                let a: Vec<u32> = shim[r].iter().map(|x| x.to_bits()).collect();
                let b: Vec<u32> = planned[r].iter().map(|x| x.to_bits()).collect();
                assert_eq!(a, b, "{algo}: shim diverges from the uniform plan at rank {r}");
            }
        }
    }

    #[test]
    fn one_stage_collectives_take_uniform_plans_only() {
        let topo = Topology::new(presets::h800(), 4);
        let c4 = codec("int4@32");
        let uniform = crate::plan::CommPlan::uniform(Algo::TwoStep, c4);
        let mixed = crate::plan::CommPlan {
            stage_codecs: crate::plan::StageCodecs::with_cross(c4, codec("int2-sr@32!")),
            ..crate::plan::CommPlan::uniform(Algo::Hier, c4)
        };
        let data = per_rank_data(4, 1000);
        let dref = &data;
        let (results, _) = run_ranks(&topo, |h| {
            let mut comm = Communicator::from_handle(h);
            let mut d = dref[comm.rank()].clone();
            // Mixed plans are a clean Shape error on every one-stage
            // collective — nothing silently drops the cross codec.
            let e = comm.reduce_scatter_planned(&mut d, &mixed).unwrap_err();
            assert!(matches!(e, CommError::Shape { .. }), "{e}");
            assert!(e.to_string().contains("uniform"), "{e}");
            let e = comm.all_gather_planned(&mut d, &mixed).unwrap_err();
            assert!(matches!(e, CommError::Shape { .. }), "{e}");
            let e = comm.broadcast_planned(&mut d, 0, &mixed).unwrap_err();
            assert!(matches!(e, CommError::Shape { .. }), "{e}");
            let sends = vec![vec![1.0f32; 8]; 4];
            let e = comm.all2all_planned(&sends, &mixed).unwrap_err();
            assert!(matches!(e, CommError::Shape { .. }), "{e}");
            // The uniform plan composes to the two-step, like the raw API.
            let own = comm.reduce_scatter_planned(&mut d, &uniform).unwrap();
            assert_eq!(own, crate::comm::chunk_range(1000, 4, comm.rank()));
            comm.all_gather_planned(&mut d, &uniform).unwrap();
            d
        });
        let (direct, _) = run_ranks(&topo, |h| {
            let mut comm = Communicator::from_handle(h);
            let mut d = dref[comm.rank()].clone();
            comm.allreduce(&mut d, &c4, AlgoPolicy::Fixed(Algo::TwoStep)).unwrap();
            d
        });
        for r in 0..4 {
            let a: Vec<u32> = results[r].iter().map(|x| x.to_bits()).collect();
            let b: Vec<u32> = direct[r].iter().map(|x| x.to_bits()).collect();
            assert_eq!(a, b, "rank {r}");
        }
    }

    #[test]
    fn planned_group_runs_fixed_mixed_plans_end_to_end() {
        let topo = Topology::new(presets::l40(), 8);
        let c4 = codec("int4@32");
        let plan = crate::plan::CommPlan {
            stage_codecs: crate::plan::StageCodecs::with_cross(c4, codec("int2-sr@32!")),
            ..crate::plan::CommPlan::uniform(Algo::Hier, c4)
        };
        let mut group =
            LocalGroup::new_planned(&topo, crate::plan::PlanPolicy::Fixed(plan)).unwrap();
        let mut data = per_rank_data(8, 2048);
        let mut exact = vec![0f32; 2048];
        for v in &data {
            for (e, x) in exact.iter_mut().zip(v) {
                *e += *x;
            }
        }
        assert_eq!(group.allreduce(&mut data, &c4).unwrap(), Algo::Hier);
        for r in &data {
            assert_eq!(r, &data[0], "ranks must agree bitwise under a mixed plan");
        }
        let s = sqnr_db(&exact, &data[0]);
        assert!(s > 5.0, "mixed-plan SQNR {s}");
        // An inadmissible fixed plan fails at construction, not per call.
        let flat = Topology::new(presets::h800(), 4);
        let e = LocalGroup::new_planned(&flat, crate::plan::PlanPolicy::Fixed(plan)).unwrap_err();
        assert!(matches!(e, CommError::Topology { algo: Algo::Hier, .. }), "{e}");
    }

    #[test]
    fn inter_gbps_preset_models_multinode_clusters() {
        let duo = preset_topo_custom(8, Some(4), Some(25.0), AlgoPolicy::Auto).unwrap();
        assert_eq!((duo.numa_groups, duo.group_size()), (4, 2));
        assert_eq!(duo.inter_bw(), Some(25e9));
        assert_eq!(duo.spec.name, "H800");
        // No override delegates to the plain grouped preset.
        let plain = preset_topo_custom(8, Some(2), None, AlgoPolicy::Auto).unwrap();
        assert_eq!(plain.spec.name, "L40");
        // Hostile values are clean shape errors.
        for (g, gbps) in [(Some(1), Some(25.0)), (Some(2), Some(0.0)), (Some(2), Some(-3.0))] {
            let e = preset_topo_custom(8, g, gbps, AlgoPolicy::Auto).unwrap_err();
            assert!(matches!(e, CommError::Shape { .. }), "{e}");
        }
    }

    #[test]
    fn preset_topo_shapes() {
        assert!(preset_topo(1, AlgoPolicy::Auto).is_err());
        assert!(preset_topo(3, AlgoPolicy::Fixed(Algo::Hier)).is_err());
        assert!(preset_topo(3, AlgoPolicy::Auto).unwrap().spec.name == "H800");
        assert!(preset_topo(4, AlgoPolicy::Auto).unwrap().spec.is_numa());
        assert!(preset_topo(4, AlgoPolicy::Fixed(Algo::TwoStep)).unwrap().spec.name == "H800");
        assert!(preset_topo(6, AlgoPolicy::Fixed(Algo::HierPipelined)).unwrap().spec.is_numa());
    }

    #[test]
    fn preset_topo_grouped_shapes() {
        let g4 = preset_topo_grouped(8, Some(4), AlgoPolicy::Auto).unwrap();
        assert_eq!((g4.numa_groups, g4.group_size()), (4, 2));
        let flat = preset_topo_grouped(8, Some(1), AlgoPolicy::Auto).unwrap();
        assert_eq!(flat.numa_groups, 1);
        // Hostile shapes from the CLI are clean errors, never panics.
        let e = preset_topo_grouped(6, Some(4), AlgoPolicy::Auto).unwrap_err();
        assert!(matches!(e, CommError::Shape { .. }), "{e}");
        assert!(e.to_string().contains("equal groups"), "{e}");
        // --groups 0 is rejected, not coerced to a flat node.
        let e = preset_topo_grouped(8, Some(0), AlgoPolicy::Auto).unwrap_err();
        assert!(e.to_string().contains("at least 1 group"), "{e}");
        // A fixed hierarchical policy on a flattened grouping fails once,
        // up front, through the same admissibility source of truth.
        let e = preset_topo_grouped(8, Some(1), AlgoPolicy::Fixed(Algo::Hier)).unwrap_err();
        assert!(matches!(e, CommError::Topology { algo: Algo::Hier, .. }), "{e}");
        // Odd worlds split into odd group counts are fine.
        let g3 = preset_topo_grouped(9, Some(3), AlgoPolicy::Fixed(Algo::Hier)).unwrap();
        assert_eq!(g3.group_size(), 3);
    }

    #[test]
    fn auto_picks_hier_on_the_dual_nvlink_cluster() {
        // The SDP4Bit-style scenario: two flat NVLink nodes joined by a
        // slow inter-node link. Above the crossover the hierarchical
        // family must win (the two-step pushes 4M across the slow link,
        // the leader ring only M); far below it, launch latency favors the
        // one-shot two-step.
        let duo = presets::dual_nvlink_node(16).unwrap();
        let c = codec("int4@32");
        let large = AlgoPolicy::Auto.resolve(&duo, &c, 32 * MB);
        assert!(
            matches!(large, Algo::Hier | Algo::HierPipelined),
            "duo large: {large:?}"
        );
        let small = AlgoPolicy::Auto.resolve(&duo, &c, 512);
        assert_eq!(small, Algo::TwoStep, "duo small");
    }

    #[test]
    fn grouped_local_group_runs_hier_end_to_end() {
        let mut group =
            LocalGroup::for_policy_grouped(8, Some(4), AlgoPolicy::Fixed(Algo::Hier)).unwrap();
        assert_eq!(group.topo().numa_groups, 4);
        let c = codec("int8");
        let mut data = per_rank_data(8, 1024);
        let mut exact = vec![0f32; 1024];
        for v in &data {
            for (e, x) in exact.iter_mut().zip(v) {
                *e += *x;
            }
        }
        assert_eq!(group.allreduce(&mut data, &c).unwrap(), Algo::Hier);
        for r in &data {
            assert_eq!(r, &data[0], "ranks must agree bitwise");
        }
        let s = sqnr_db(&exact, &data[0]);
        assert!(s > 24.0, "G=4 group SQNR {s}");
    }

    #[test]
    fn group_shape_errors() {
        let mut group = LocalGroup::for_policy(4, AlgoPolicy::Auto).unwrap();
        let mut three = per_rank_data(3, 64);
        let e = group.allreduce(&mut three, &Codec::Bf16).unwrap_err();
        assert!(matches!(e, CommError::Shape { .. }), "{e}");
        let mut ragged = per_rank_data(4, 64);
        ragged[2].pop();
        let e = group.allreduce(&mut ragged, &Codec::Bf16).unwrap_err();
        assert!(matches!(e, CommError::Shape { .. }), "{e}");
    }

    #[test]
    fn reduce_scatter_all_gather_compose_to_twostep() {
        // The two-step IS reduce_scatter ∘ all_gather — composing the public
        // primitives must be bit-identical to Fixed(TwoStep).
        let topo = Topology::new(presets::h800(), 4);
        let c = codec("int4@32");
        let data = per_rank_data(4, 1000);
        let dref = &data;
        let (composed, _) = run_ranks(&topo, |h| {
            let mut comm = Communicator::from_handle(h);
            let mut d = dref[comm.rank()].clone();
            let own = comm.reduce_scatter(&mut d, &c).unwrap();
            assert_eq!(own, crate::comm::chunk_range(1000, 4, comm.rank()));
            comm.all_gather(&mut d, &c).unwrap();
            d
        });
        let (direct, _) = run_ranks(&topo, |h| {
            let mut comm = Communicator::from_handle(h);
            let mut d = dref[comm.rank()].clone();
            comm.allreduce(&mut d, &c, AlgoPolicy::Fixed(Algo::TwoStep)).unwrap();
            d
        });
        for r in 0..4 {
            let a: Vec<u32> = composed[r].iter().map(|x| x.to_bits()).collect();
            let b: Vec<u32> = direct[r].iter().map(|x| x.to_bits()).collect();
            assert_eq!(a, b, "rank {r}");
        }
    }

    #[test]
    fn broadcast_delivers_wire_precision_bit_identically() {
        let topo = Topology::new(presets::h800(), 4);
        let c = codec("int5");
        let mut rng = Prng::new(99);
        let mut payload = vec![0f32; 777];
        rng.fill_activations(&mut payload, 1.0);
        let pref = &payload;
        let (results, _) = run_ranks(&topo, |h| {
            let mut comm = Communicator::from_handle(h);
            let mut d = if comm.rank() == 2 { pref.clone() } else { vec![0f32; 777] };
            comm.broadcast(&mut d, 2, &c).unwrap();
            d
        });
        for r in &results {
            assert_eq!(
                r.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                results[0].iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "all ranks agree bitwise (root self-QDQs)"
            );
        }
        let s = sqnr_db(&payload, &results[0]);
        assert!(s > 14.0, "broadcast wire quality {s} dB");
        // Bad root is a clean shape error.
        let (errs, _) = run_ranks(&topo, |h| {
            let mut comm = Communicator::from_handle(h);
            let mut d = vec![0f32; 8];
            comm.broadcast(&mut d, 9, &c).unwrap_err().to_string()
        });
        assert!(errs[0].contains("root"), "{}", errs[0]);
    }
}
