//! Pipelined hierarchical AllReduce (Fig. 8), generalized over G groups.
//!
//! The payload is split into micro-chunks; each flows through the three
//! hierarchical stages (intra RS → cross-group column ring → intra AG)
//! with the sends of later micro-chunks issued before earlier ones finish —
//! the software-pipelining structure that lets the intra-group fabric and
//! the inter-group link overlap on real hardware. In this in-process
//! fabric the overlap has no wall-clock meaning (timing lives in
//! [`crate::sim`]); what this module establishes is *functional
//! equivalence*: the chunked, reordered schedule produces exactly the same
//! bytes and numerics as the serial execution.
//!
//! ## Bounded in-flight window
//!
//! Intra-RS sends are issued at most a window of micro-chunks ahead of
//! the chunk currently being reduced ([`SEND_WINDOW`] by default; a
//! [`CommPlan`](crate::plan::CommPlan) or `--window` chooses per call —
//! the all-gather phase always ships one chunk at a time), so the
//! transport's peak buffered wire bytes are
//! bounded by a handful of micro-chunks instead of growing with the whole
//! payload — the old schedule posted all k×(s−1) RS sends before the first
//! recv, which on the TCP backend meant the receive queues briefly held
//! most of the encoded payload. The window still keeps the next chunk's RS
//! traffic in flight while the current chunk crosses the inter-group link
//! (the Fig. 8 overlap), and the bound is pinned in a test via
//! [`TransportStats::peak_buffered_bytes`](crate::transport::TransportStats).

use super::{chunk_range, communicator::Communicator, encode, error::CommError, hier, Algo};
use crate::comm::fabric::RankHandle;
use crate::plan::StageCodecs;
use crate::quant::{Codec, CodecBuffers};
use crate::record;
use crate::telemetry::{codec_tag, Op, Stage};
use crate::transport::Transport;

/// Default micro-chunk count (the sim's Fig. 8 sweep peaks around 8).
/// A [`CommPlan`](crate::plan::CommPlan) overrides this per call — the
/// compiler's search replaces the constant; this remains the
/// `AlgoPolicy`-shim default.
pub const DEFAULT_CHUNKS: usize = 8;

/// Default in-flight window: how many micro-chunks of intra-RS traffic may
/// be issued ahead of the chunk currently being reduced. `>= 2` keeps the
/// pipeline overlap (chunk c's cross-group hop runs while chunk c+1's RS
/// payloads travel); the in-flight memory bound scales linearly with it.
/// Like [`DEFAULT_CHUNKS`], a plan overrides this per call (`--window`).
pub const SEND_WINDOW: usize = 2;

/// Issue the intra-group RS sends for one micro-chunk.
fn send_rs_chunk<T: Transport>(
    h: &RankHandle<T>,
    bufs: &mut CodecBuffers,
    codec: &Codec,
    data: &[f32],
    k: usize,
    chunk: usize,
    threads: usize,
) -> Result<(), CommError> {
    let topo = h.topo();
    let s = topo.group_size();
    let group = topo.group_members(h.rank);
    let mr = chunk_range(data.len(), k, chunk);
    let micro = &data[mr];
    if let Some(rec) = h.recorder() {
        rec.set_stage(Stage::ReduceScatter, codec_tag(codec));
        rec.set_chunk(chunk as u32);
    }
    for peer_j in 0..s {
        let peer = group.start + peer_j;
        if peer != h.rank {
            let r = chunk_range(micro.len(), s, peer_j);
            record!(h.recorder(), start Op::Encode, r.len() as u64);
            let wire = encode(codec, &micro[r], bufs, threads)?;
            record!(h.recorder(), end Op::Encode, wire.len() as u64);
            h.send(peer, wire)?;
        }
    }
    Ok(())
}

/// In-place pipelined hierarchical AllReduce with `chunks` micro-chunks,
/// `window` chunks of in-flight intra-RS traffic, and one codec per stage
/// — the plan execution path (see [`hier::allreduce_staged`] for the
/// per-stage QDQ contract).
pub(crate) fn allreduce_planned<T: Transport>(
    c: &mut Communicator<T>,
    data: &mut [f32],
    stages: &StageCodecs,
    chunks: usize,
    window: usize,
) -> Result<(), CommError> {
    let Communicator { handle: h, bufs, reduced, codec_threads, .. } = c;
    let t = *codec_threads;
    let topo = h.topo().clone();
    Algo::HierPipelined.admissible(&topo)?;
    let s = topo.group_size();
    let group = topo.group_members(h.rank);
    let j = h.rank - group.start;
    let k = chunks.max(1);
    let win = window.max(1);

    // Phase A (windowed): prime the pipeline with the first `win` chunks'
    // intra-RS sends — enough to keep the intra fabric busy while chunk 0
    // crosses the inter-group link, without buffering the whole payload.
    for chunk in 0..k.min(win) {
        send_rs_chunk(h, bufs, &stages.intra_rs, data, k, chunk, t)?;
    }

    // Phase B: per micro-chunk: reduce own sub-chunk, run the cross-group
    // column ring, then top the send window back up — chunk c's cross hop
    // happens while chunk c+1's RS payloads are already in flight. The
    // per-chunk accumulators live in the communicator and are reused
    // across calls.
    if reduced.len() < k {
        reduced.resize_with(k, Vec::new);
    }
    for chunk in 0..k {
        let mr = chunk_range(data.len(), k, chunk);
        let micro = &data[mr.clone()];
        let own = chunk_range(micro.len(), s, j);
        let acc = &mut reduced[chunk];
        acc.clear();
        acc.extend_from_slice(&micro[own]);
        if let Some(rec) = h.recorder() {
            rec.set_stage(Stage::ReduceScatter, codec_tag(&stages.intra_rs));
            rec.set_chunk(chunk as u32);
        }
        for peer_j in 0..s {
            let peer = group.start + peer_j;
            if peer != h.rank {
                let wire = h.recv(peer)?;
                record!(h.recorder(), start Op::DecodeSum, acc.len() as u64);
                Codec::decode_sum_with_threads(&wire, bufs, acc, t)
                    .map_err(|e| CommError::decode(peer, e))?;
                record!(h.recorder(), end Op::DecodeSum, wire.len() as u64);
            }
        }
        // Cross-group column ring for this micro-chunk: the G encoded
        // partials circulate verbatim and every member decode-sums them in
        // group order (one shared implementation — see hier.rs), so all
        // groups stay bit-identical. The slow-tier stage: its codec may
        // be more aggressive than the intra stages'.
        hier::cross_group_reduce(h, bufs, acc, &stages.cross, t, &topo)?;
        // Keep `win` chunks of RS traffic in flight ahead of the reducer.
        if chunk + win < k {
            send_rs_chunk(h, bufs, &stages.intra_rs, data, k, chunk + win, t)?;
        }
    }

    // Phase C: all-gather, one micro-chunk at a time (send chunk c, then
    // collect chunk c) — per-link FIFO keeps senders and receivers in
    // step, and at most ~one chunk per link is ever queued.
    for chunk in 0..k {
        let acc = &reduced[chunk];
        if let Some(rec) = h.recorder() {
            rec.set_stage(Stage::AllGather, codec_tag(&stages.intra_ag));
            rec.set_chunk(chunk as u32);
        }
        record!(h.recorder(), start Op::Encode, acc.len() as u64);
        let wire = encode(&stages.intra_ag, acc, bufs, t)?;
        record!(h.recorder(), end Op::Encode, wire.len() as u64);
        for peer_j in 0..s {
            let p = group.start + peer_j;
            if p != h.rank {
                h.send(p, wire.clone())?;
            }
        }
        let mr = chunk_range(data.len(), k, chunk);
        let own = chunk_range(mr.len(), s, j);
        let own_abs = mr.start + own.start..mr.start + own.end;
        record!(h.recorder(), start Op::Decode, own_abs.len() as u64);
        Codec::decode_with_threads(&wire, bufs, &mut data[own_abs], t)
            .map_err(|e| CommError::decode(h.rank, e))?;
        record!(h.recorder(), end Op::Decode, wire.len() as u64);
        for peer_j in 0..s {
            let p = group.start + peer_j;
            if p != h.rank {
                let wire = h.recv(p)?;
                let r = chunk_range(mr.len(), s, peer_j);
                let abs = mr.start + r.start..mr.start + r.end;
                record!(h.recorder(), start Op::Decode, abs.len() as u64);
                Codec::decode_with_threads(&wire, bufs, &mut data[abs], t)
                    .map_err(|e| CommError::decode(p, e))?;
                record!(h.recorder(), end Op::Decode, wire.len() as u64);
            }
        }
    }
    Ok(())
}

/// In-place pipelined hierarchical AllReduce with one codec everywhere
/// and the default window — the uniform special case of
/// [`allreduce_planned`] (the `AlgoPolicy` shim and the explicit
/// [`Communicator::allreduce_chunked`] knob).
pub(crate) fn allreduce_chunked<T: Transport>(
    c: &mut Communicator<T>,
    data: &mut [f32],
    codec: &Codec,
    chunks: usize,
) -> Result<(), CommError> {
    allreduce_planned(c, data, &StageCodecs::uniform(*codec), chunks, SEND_WINDOW)
}

/// Pipelined hierarchical AllReduce with the default micro-chunk count.
pub(crate) fn allreduce<T: Transport>(
    c: &mut Communicator<T>,
    data: &mut [f32],
    codec: &Codec,
) -> Result<(), CommError> {
    allreduce_chunked(c, data, codec, DEFAULT_CHUNKS)
}

/// Reference: serial hierarchical execution of the same chunking (used by
/// the equivalence test and the Fig. 8 "serial" bar).
#[cfg(test)]
pub(crate) fn allreduce_serial_chunked<T: Transport>(
    c: &mut Communicator<T>,
    data: &mut [f32],
    codec: &Codec,
    chunks: usize,
) -> Result<(), CommError> {
    let k = chunks.max(1);
    for chunk in 0..k {
        let mr = chunk_range(data.len(), k, chunk);
        let mut micro = data[mr.clone()].to_vec();
        hier::allreduce(c, &mut micro, codec)?;
        data[mr].copy_from_slice(&micro);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::testutil::harness;
    use crate::quant::Codec;
    use crate::topo::{presets, Topology};
    use crate::util::stats::sqnr_db;

    #[test]
    fn matches_serial_hier_bit_exactly() {
        // Pipelining must not change the numerics at all — at G = 2 and on
        // the generalized 4-group topology.
        for topo in [Topology::new(presets::l40(), 8), presets::four_group_pcie(8).unwrap()] {
            for spec in ["bf16", "int8", "int4@32", "int2-sr@32!"] {
                let codec = Codec::parse(spec).unwrap();
                let (pp, _) =
                    harness(&topo, 4096, &codec, |c, d, k| allreduce_chunked(c, d, k, 8));
                let (serial, _) =
                    harness(&topo, 4096, &codec, |c, d, k| allreduce_serial_chunked(c, d, k, 8));
                assert_eq!(
                    pp[0], serial[0],
                    "{spec} G={}: pipelined != serial",
                    topo.numa_groups
                );
            }
        }
    }

    #[test]
    fn correct_for_any_chunk_count() {
        let topo = Topology::new(presets::l40(), 8);
        let codec = Codec::parse("int5").unwrap();
        for k in [1usize, 2, 3, 8, 16] {
            let (results, expected) =
                harness(&topo, 2500, &codec, |c, d, cd| allreduce_chunked(c, d, cd, k));
            for r in &results {
                assert_eq!(r, &results[0], "k={k}");
            }
            let s = sqnr_db(&expected, &results[0]);
            assert!(s > 14.0, "k={k}: SQNR {s}");
        }
    }

    #[test]
    fn micro_chunking_grouping_overhead_is_bounded() {
        // Finer chunks mean more (smaller) quantization groups on the wire;
        // wire volume must not grow by more than the per-chunk meta bound.
        let topo = Topology::new(presets::l40(), 8);
        let codec = Codec::parse("int4@32").unwrap();
        let len = 8192usize;
        let measure = |k: usize| {
            let inputs: Vec<f32> = (0..len).map(|i| (i as f32).sin()).collect();
            let ir = &inputs;
            let (_, c) = crate::comm::fabric::run_ranks(&topo, |h| {
                let mut comm = Communicator::from_handle(h);
                let mut d = ir.clone();
                allreduce_chunked(&mut comm, &mut d, &codec, k).unwrap();
            });
            c.total_bytes()
        };
        let v1 = measure(1) as f64;
        let v16 = measure(16) as f64;
        assert!(v16 / v1 < 1.30, "chunking overhead {}", v16 / v1);
    }

    #[test]
    fn in_flight_bytes_bounded_by_the_send_window() {
        // The memory-bound pin: with k micro-chunks, the mesh-wide peak of
        // undelivered payload bytes must stay near (SEND_WINDOW + slack)
        // chunks' worth of traffic — the pre-window schedule buffered all
        // k×(s−1) RS wires (~40% of total traffic) before the first recv.
        let topo = Topology::new(presets::l40(), 8);
        let codec = Codec::parse("int4@32").unwrap();
        let len = 65536usize;
        let k = 32usize;
        let inputs: Vec<f32> = (0..len).map(|i| (i as f32).sin()).collect();
        let ir = &inputs;
        let (stats, _) = crate::comm::fabric::run_ranks(&topo, |h| {
            let mut comm = Communicator::from_handle(h);
            let mut d = ir.clone();
            allreduce_chunked(&mut comm, &mut d, &codec, k).unwrap();
            comm.transport().stats()
        });
        // InProc counters are mesh-shared and monotone (totals and peak
        // only ever grow), so the max over the per-rank snapshots — the
        // last rank to finish sees everything — is the run's true value.
        // (`buffered_bytes` itself is racy mid-run and not asserted.)
        let peak = stats.iter().map(|s| s.peak_buffered_bytes).max().unwrap();
        let total = stats.iter().map(|s| s.payload_bytes).max().unwrap();
        assert!(peak > 0);
        // Window bound with slack for rank skew (ranks may run up to a
        // window apart): a few chunks' worth of the total, never a payload
        // fraction like the old all-upfront schedule's ~40%.
        let per_chunk = total / k as u64;
        let bound = (3 * SEND_WINDOW as u64 + 4) * per_chunk;
        assert!(
            peak <= bound,
            "peak in-flight {peak} exceeds the window bound {bound} ({total} total)"
        );
        assert!(
            peak < total / 3,
            "peak in-flight {peak} should be far below the full payload traffic {total}"
        );
    }

    #[test]
    fn peak_buffered_bytes_scale_with_the_chosen_window() {
        // The --window knob is real: a larger plan window must buffer
        // proportionally more in-flight traffic (and every window stays
        // within its own bound), while the numerics are identical.
        let topo = Topology::new(presets::l40(), 8);
        let codec = Codec::parse("int4@32").unwrap();
        let stages = StageCodecs::uniform(codec);
        let len = 65536usize;
        let k = 32usize;
        let inputs: Vec<f32> = (0..len).map(|i| (i as f32).sin()).collect();
        let ir = &inputs;
        let run = |win: usize| {
            let (out, _) = crate::comm::fabric::run_ranks(&topo, |h| {
                let mut comm = Communicator::from_handle(h);
                let mut d = ir.clone();
                allreduce_planned(&mut comm, &mut d, &stages, k, win).unwrap();
                (comm.transport().stats(), d)
            });
            let peak = out.iter().map(|(s, _)| s.peak_buffered_bytes).max().unwrap();
            let total = out.iter().map(|(s, _)| s.payload_bytes).max().unwrap();
            let bits: Vec<u32> = out[0].1.iter().map(|x| x.to_bits()).collect();
            (peak, total, bits)
        };
        let (p2, total, r2) = run(2);
        let (p8, _, r8) = run(8);
        assert_eq!(r2, r8, "the window must never change the numerics");
        let per_chunk = total / k as u64;
        assert!(
            p8 > p2 + per_chunk / 2,
            "window 8 peak {p8} should sit clearly above window 2 peak {p2} \
             (per-chunk traffic {per_chunk})"
        );
        assert!(p8 <= (3 * 8 + 4) * per_chunk, "window 8 peak {p8} outside its own bound");
    }

    #[test]
    fn mixed_stage_pipeline_matches_serial_staged_hier_bit_exactly() {
        // Pipelining must be numerics-neutral for mixed-stage plans too:
        // chunked+windowed execution == serial per-chunk staged hier.
        for topo in [Topology::new(presets::l40(), 8), presets::four_group_pcie(8).unwrap()] {
            let stages = StageCodecs::with_cross(
                Codec::parse("int4@32").unwrap(),
                Codec::parse("int2-sr@32!").unwrap(),
            );
            for win in [1usize, 2, 5] {
                let (pp, _) = harness(&topo, 4096, &Codec::Bf16, |c, d, _| {
                    allreduce_planned(c, d, &stages, 8, win)
                });
                let (serial, _) = harness(&topo, 4096, &Codec::Bf16, |c, d, _| {
                    let k = 8;
                    for chunk in 0..k {
                        let mr = chunk_range(d.len(), k, chunk);
                        let mut micro = d[mr.clone()].to_vec();
                        hier::allreduce_staged(c, &mut micro, &stages)?;
                        d[mr].copy_from_slice(&micro);
                    }
                    Ok(())
                });
                assert_eq!(
                    pp[0], serial[0],
                    "win={win} G={}: mixed pipelined != serial staged",
                    topo.numa_groups
                );
            }
        }
    }
}
