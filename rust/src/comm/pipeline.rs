//! Pipelined hierarchical AllReduce (Fig. 8).
//!
//! The payload is split into micro-chunks; each flows through the three
//! hierarchical stages (intra RS → cross-NUMA reduce → intra AG) with the
//! sends of later micro-chunks issued before earlier ones finish — the
//! software-pipelining structure that lets PCIe and NUMA-bridge traffic
//! overlap on real hardware. In this in-process fabric the overlap has no
//! wall-clock meaning (timing lives in [`crate::sim`]); what this module
//! establishes is *functional equivalence*: the chunked, reordered schedule
//! produces exactly the same bytes and numerics as the serial execution.

use super::{chunk_range, communicator::Communicator, encode, error::CommError, hier, Algo};
use crate::quant::Codec;
use crate::transport::Transport;

/// Default micro-chunk count (the sim's Fig. 8 sweep peaks around 8).
pub const DEFAULT_CHUNKS: usize = 8;

/// In-place pipelined hierarchical AllReduce with `chunks` micro-chunks.
pub(crate) fn allreduce_chunked<T: Transport>(
    c: &mut Communicator<T>,
    data: &mut [f32],
    codec: &Codec,
    chunks: usize,
) -> Result<(), CommError> {
    let Communicator { handle: h, bufs, reduced, codec_threads, .. } = c;
    let t = *codec_threads;
    let topo = h.topo().clone();
    if topo.numa_groups != 2 {
        return Err(CommError::topology(
            Algo::HierPipelined,
            format!("needs 2 NUMA groups, topology has {}", topo.numa_groups),
        ));
    }
    let s = topo.group_size();
    let group = topo.group_members(h.rank);
    let j = h.rank - group.start;
    let k = chunks.max(1);

    // Phase A: issue ALL intra-RS sends for every micro-chunk up front —
    // this is what fills the PCIe bus while the bridge works (Fig. 8).
    for chunk in 0..k {
        let mr = chunk_range(data.len(), k, chunk);
        let micro = &data[mr.clone()];
        for peer_j in 0..s {
            let peer = group.start + peer_j;
            if peer != h.rank {
                let r = chunk_range(micro.len(), s, peer_j);
                h.send(peer, encode(codec, &micro[r], bufs, t))?;
            }
        }
    }

    // Phase B: per micro-chunk: reduce own sub-chunk, run the bridge
    // exchange, then all-gather — chunk c's bridge work happens while
    // chunk c+1's RS payloads are already in flight. The per-chunk
    // accumulators live in the communicator and are reused across calls.
    if reduced.len() < k {
        reduced.resize_with(k, Vec::new);
    }
    for chunk in 0..k {
        let mr = chunk_range(data.len(), k, chunk);
        let micro = &data[mr.clone()];
        let own = chunk_range(micro.len(), s, j);
        let acc = &mut reduced[chunk];
        acc.clear();
        acc.extend_from_slice(&micro[own]);
        for peer_j in 0..s {
            let peer = group.start + peer_j;
            if peer != h.rank {
                let wire = h.recv(peer)?;
                Codec::decode_sum_with_threads(&wire, bufs, acc, t)
                    .map_err(|e| CommError::decode(peer, e))?;
            }
        }
        // Bridge exchange for this micro-chunk (symmetric QDQ in group
        // order — see hier.rs — so both NUMA groups stay bit-identical).
        let peer = topo.bridge_peer(h.rank);
        let wire_mine = encode(codec, acc, bufs, t);
        h.send(peer, wire_mine.clone())?;
        let wire_peer = h.recv(peer)?;
        // Decode failures name the payload's actual source (see hier.rs).
        let (first, f_src, second, s_src) = if h.rank < peer {
            (&wire_mine, h.rank, &wire_peer, peer)
        } else {
            (&wire_peer, peer, &wire_mine, h.rank)
        };
        acc.iter_mut().for_each(|x| *x = 0.0);
        Codec::decode_sum_with_threads(first, bufs, acc, t)
            .map_err(|e| CommError::decode(f_src, e))?;
        Codec::decode_sum_with_threads(second, bufs, acc, t)
            .map_err(|e| CommError::decode(s_src, e))?;
    }

    // Phase C: all-gather every micro-chunk's reduced sub-chunk.
    for (chunk, acc) in reduced.iter().take(k).enumerate() {
        let wire = encode(codec, acc, bufs, t);
        for peer_j in 0..s {
            let p = group.start + peer_j;
            if p != h.rank {
                h.send(p, wire.clone())?;
            }
        }
        let mr = chunk_range(data.len(), k, chunk);
        let own = chunk_range(mr.len(), s, j);
        let own_abs = mr.start + own.start..mr.start + own.end;
        Codec::decode_with_threads(&wire, bufs, &mut data[own_abs], t)
            .map_err(|e| CommError::decode(h.rank, e))?;
    }
    for chunk in 0..k {
        let mr = chunk_range(data.len(), k, chunk);
        for peer_j in 0..s {
            let p = group.start + peer_j;
            if p != h.rank {
                let wire = h.recv(p)?;
                let r = chunk_range(mr.len(), s, peer_j);
                let abs = mr.start + r.start..mr.start + r.end;
                Codec::decode_with_threads(&wire, bufs, &mut data[abs], t)
                    .map_err(|e| CommError::decode(p, e))?;
            }
        }
    }
    Ok(())
}

/// Pipelined hierarchical AllReduce with the default micro-chunk count.
pub(crate) fn allreduce<T: Transport>(
    c: &mut Communicator<T>,
    data: &mut [f32],
    codec: &Codec,
) -> Result<(), CommError> {
    allreduce_chunked(c, data, codec, DEFAULT_CHUNKS)
}

/// Reference: serial hierarchical execution of the same chunking (used by
/// the equivalence test and the Fig. 8 "serial" bar).
#[cfg(test)]
pub(crate) fn allreduce_serial_chunked<T: Transport>(
    c: &mut Communicator<T>,
    data: &mut [f32],
    codec: &Codec,
    chunks: usize,
) -> Result<(), CommError> {
    let k = chunks.max(1);
    for chunk in 0..k {
        let mr = chunk_range(data.len(), k, chunk);
        let mut micro = data[mr.clone()].to_vec();
        hier::allreduce(c, &mut micro, codec)?;
        data[mr].copy_from_slice(&micro);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::testutil::harness;
    use crate::quant::Codec;
    use crate::topo::{presets, Topology};
    use crate::util::stats::sqnr_db;

    #[test]
    fn matches_serial_hier_bit_exactly() {
        // Pipelining must not change the numerics at all.
        let topo = Topology::new(presets::l40(), 8);
        for spec in ["bf16", "int8", "int4@32", "int2-sr@32!"] {
            let codec = Codec::parse(spec).unwrap();
            let (pp, _) =
                harness(&topo, 4096, &codec, |c, d, k| allreduce_chunked(c, d, k, 8));
            let (serial, _) =
                harness(&topo, 4096, &codec, |c, d, k| allreduce_serial_chunked(c, d, k, 8));
            assert_eq!(pp[0], serial[0], "{spec}: pipelined != serial");
        }
    }

    #[test]
    fn correct_for_any_chunk_count() {
        let topo = Topology::new(presets::l40(), 8);
        let codec = Codec::parse("int5").unwrap();
        for k in [1usize, 2, 3, 8, 16] {
            let (results, expected) =
                harness(&topo, 2500, &codec, |c, d, cd| allreduce_chunked(c, d, cd, k));
            for r in &results {
                assert_eq!(r, &results[0], "k={k}");
            }
            let s = sqnr_db(&expected, &results[0]);
            assert!(s > 14.0, "k={k}: SQNR {s}");
        }
    }

    #[test]
    fn micro_chunking_grouping_overhead_is_bounded() {
        // Finer chunks mean more (smaller) quantization groups on the wire;
        // wire volume must not grow by more than the per-chunk meta bound.
        let topo = Topology::new(presets::l40(), 8);
        let codec = Codec::parse("int4@32").unwrap();
        let len = 8192usize;
        let measure = |k: usize| {
            let inputs: Vec<f32> = (0..len).map(|i| (i as f32).sin()).collect();
            let ir = &inputs;
            let (_, c) = crate::comm::fabric::run_ranks(&topo, |h| {
                let mut comm = Communicator::from_handle(h);
                let mut d = ir.clone();
                allreduce_chunked(&mut comm, &mut d, &codec, k).unwrap();
            });
            c.total_bytes()
        };
        let v1 = measure(1) as f64;
        let v16 = measure(16) as f64;
        assert!(v16 / v1 < 1.30, "chunking overhead {}", v16 / v1);
    }
}
