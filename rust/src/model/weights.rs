//! Weight store: reads/writes the binary tensor bundle shared with
//! `python/compile/aot.py` (init weights) and used for rust-side
//! checkpoints, plus the TP sharding rules mirrored from python.
//!
//! Format (little-endian):
//! `u32 magic | u32 version | u32 n_tensors`, then per tensor
//! `u32 name_len | name | u8 ndim | u32 dims[] | f32 data[]`.

use std::collections::HashMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::runtime::Tensor;

pub const MAGIC: u32 = 0xF1A5;

/// An ordered named-tensor bundle (order = python `param_specs()` order).
#[derive(Debug, Clone, Default)]
pub struct Weights {
    pub names: Vec<String>,
    pub tensors: HashMap<String, Tensor>,
}

impl Weights {
    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.tensors.get(name).with_context(|| format!("missing tensor '{name}'"))
    }

    pub fn insert(&mut self, name: impl Into<String>, t: Tensor) {
        let name = name.into();
        if !self.tensors.contains_key(&name) {
            self.names.push(name.clone());
        }
        self.tensors.insert(name, t);
    }

    /// Tensors in insertion order (the flat HLO argument order).
    pub fn ordered(&self) -> Vec<&Tensor> {
        self.names.iter().map(|n| &self.tensors[n]).collect()
    }

    pub fn n_params(&self) -> usize {
        self.names.iter().map(|n| self.tensors[n].len()).sum()
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Weights> {
        let mut f = std::fs::File::open(path.as_ref())
            .with_context(|| format!("opening {:?}", path.as_ref()))?;
        let mut hdr = [0u8; 12];
        f.read_exact(&mut hdr)?;
        let magic = u32::from_le_bytes(hdr[0..4].try_into().unwrap());
        let version = u32::from_le_bytes(hdr[4..8].try_into().unwrap());
        let n = u32::from_le_bytes(hdr[8..12].try_into().unwrap());
        if magic != MAGIC || version != 1 {
            bail!("bad weights header: magic {magic:#x} version {version}");
        }
        let mut w = Weights::default();
        for _ in 0..n {
            let mut b4 = [0u8; 4];
            f.read_exact(&mut b4)?;
            let name_len = u32::from_le_bytes(b4) as usize;
            if name_len > 4096 {
                bail!("implausible name length {name_len}");
            }
            let mut name = vec![0u8; name_len];
            f.read_exact(&mut name)?;
            let name = String::from_utf8(name).context("tensor name not utf8")?;
            let mut b1 = [0u8; 1];
            f.read_exact(&mut b1)?;
            let ndim = b1[0] as usize;
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                f.read_exact(&mut b4)?;
                shape.push(u32::from_le_bytes(b4) as usize);
            }
            let count: usize = if ndim == 0 { 1 } else { shape.iter().product() };
            let mut bytes = vec![0u8; count * 4];
            f.read_exact(&mut bytes)?;
            let data: Vec<f32> = bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            w.insert(name, Tensor::new(shape, data));
        }
        Ok(w)
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut f = std::fs::File::create(path.as_ref())
            .with_context(|| format!("creating {:?}", path.as_ref()))?;
        f.write_all(&MAGIC.to_le_bytes())?;
        f.write_all(&1u32.to_le_bytes())?;
        f.write_all(&(self.names.len() as u32).to_le_bytes())?;
        for name in &self.names {
            let t = &self.tensors[name];
            f.write_all(&(name.len() as u32).to_le_bytes())?;
            f.write_all(name.as_bytes())?;
            f.write_all(&[t.shape.len() as u8])?;
            for &d in &t.shape {
                f.write_all(&(d as u32).to_le_bytes())?;
            }
            // SAFETY: reinterpreting a live &[f32] as bytes — the pointer is
            // valid for len * 4 bytes, u8 has no alignment requirement, and
            // every f32 bit pattern is a valid byte sequence.
            let bytes: &[u8] = unsafe {
                std::slice::from_raw_parts(t.data.as_ptr() as *const u8, t.data.len() * 4)
            };
            f.write_all(bytes)?;
        }
        Ok(())
    }
}

/// TP weight slicing — the mirror of python `shard_param`:
/// column-parallel (`wq/wk/wv/w1`) split the last axis; row-parallel
/// (`wo/w2`) split the first; everything else is replicated.
pub fn shard_param(name: &str, t: &Tensor, tp: usize, shard: usize) -> Tensor {
    assert!(shard < tp);
    let base = name.rsplit('.').next().unwrap();
    match base {
        "wq" | "wk" | "wv" | "w1" => {
            let (rows, cols) = (t.shape[0], t.shape[1]);
            assert_eq!(cols % tp, 0, "{name}: cols {cols} % tp {tp}");
            let w = cols / tp;
            let mut data = Vec::with_capacity(rows * w);
            for r in 0..rows {
                let row = &t.data[r * cols..(r + 1) * cols];
                data.extend_from_slice(&row[shard * w..(shard + 1) * w]);
            }
            Tensor::new(vec![rows, w], data)
        }
        "wo" | "w2" => {
            let (rows, cols) = (t.shape[0], t.shape[1]);
            assert_eq!(rows % tp, 0, "{name}: rows {rows} % tp {tp}");
            let h = rows / tp;
            let data = t.data[shard * h * cols..(shard + 1) * h * cols].to_vec();
            Tensor::new(vec![h, cols], data)
        }
        _ => t.clone(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Weights {
        let mut w = Weights::default();
        w.insert("embed", Tensor::new(vec![4, 2], (0..8).map(|i| i as f32).collect()));
        w.insert("l0.wq", Tensor::new(vec![2, 4], (0..8).map(|i| i as f32 * 0.5).collect()));
        w.insert("l0.wo", Tensor::new(vec![4, 2], (0..8).map(|i| -(i as f32)).collect()));
        w.insert("lnf_g", Tensor::new(vec![2], vec![1.0, 1.0]));
        w
    }

    #[test]
    fn save_load_roundtrip() {
        let w = toy();
        let dir = std::env::temp_dir().join(format!("fcw_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("w.bin");
        w.save(&p).unwrap();
        let back = Weights::load(&p).unwrap();
        assert_eq!(back.names, w.names);
        for n in &w.names {
            assert_eq!(back.tensors[n], w.tensors[n], "{n}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn loads_python_init_weights_if_built() {
        let p = crate::runtime::default_artifacts_dir().join("tiny_init_weights.bin");
        if !p.exists() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let w = Weights::load(p).unwrap();
        assert_eq!(w.names[0], "embed");
        assert_eq!(w.tensors["embed"].shape, vec![2048, 256]);
        assert_eq!(w.n_params(), 3674624);
        // LayerNorm gains come in as ones.
        assert!(w.tensors["l0.ln1_g"].data.iter().all(|&x| x == 1.0));
    }

    #[test]
    fn column_shard_splits_last_axis() {
        let w = toy();
        let full = w.get("l0.wq").unwrap();
        let s0 = shard_param("l0.wq", full, 2, 0);
        let s1 = shard_param("l0.wq", full, 2, 1);
        assert_eq!(s0.shape, vec![2, 2]);
        // Row 0 of full is [0, .5, 1, 1.5]: shard0 gets [0, .5].
        assert_eq!(s0.data, vec![0.0, 0.5, 2.0, 2.5]);
        assert_eq!(s1.data, vec![1.0, 1.5, 3.0, 3.5]);
    }

    #[test]
    fn row_shard_splits_first_axis() {
        let w = toy();
        let full = w.get("l0.wo").unwrap();
        let s1 = shard_param("l0.wo", full, 2, 1);
        assert_eq!(s1.shape, vec![2, 2]);
        assert_eq!(s1.data, vec![-4.0, -5.0, -6.0, -7.0]);
    }

    #[test]
    fn replicated_params_pass_through() {
        let w = toy();
        let full = w.get("lnf_g").unwrap();
        assert_eq!(&shard_param("lnf_g", full, 4, 3), full);
        let emb = w.get("embed").unwrap();
        assert_eq!(&shard_param("embed", emb, 4, 0), emb);
    }
}
