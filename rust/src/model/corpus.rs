//! Corpus loader + deterministic batch sampler.
//!
//! Reads the binary token stream written by `python/compile/corpus.py`
//! (u16 magic | u16 version | u32 vocab | u64 n | u16 tokens[], LE) and
//! serves next-token-prediction batches. Train/eval split matches the
//! python side: eval = final 5 %.

use std::io::Read;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::Prng;

pub const MAGIC: u16 = 0xC0A9;

/// A token stream with its vocabulary size.
#[derive(Debug, Clone)]
pub struct Corpus {
    pub vocab: usize,
    pub tokens: Vec<u16>,
}

/// One next-token batch: `tokens[b][s]` predicts `targets[b][s]`.
#[derive(Debug, Clone)]
pub struct Batch {
    pub tokens: Vec<i32>,
    pub targets: Vec<i32>,
    pub batch: usize,
    pub seq: usize,
}

impl Corpus {
    pub fn load(path: impl AsRef<Path>) -> Result<Corpus> {
        let mut f = std::fs::File::open(path.as_ref())
            .with_context(|| format!("opening corpus {:?}", path.as_ref()))?;
        let mut hdr = [0u8; 16];
        f.read_exact(&mut hdr)?;
        let magic = u16::from_le_bytes([hdr[0], hdr[1]]);
        let version = u16::from_le_bytes([hdr[2], hdr[3]]);
        let vocab = u32::from_le_bytes(hdr[4..8].try_into().unwrap()) as usize;
        let n = u64::from_le_bytes(hdr[8..16].try_into().unwrap()) as usize;
        if magic != MAGIC || version != 1 {
            bail!("bad corpus header (magic {magic:#x}, version {version})");
        }
        let mut bytes = vec![0u8; 2 * n];
        f.read_exact(&mut bytes)?;
        let tokens: Vec<u16> =
            bytes.chunks_exact(2).map(|c| u16::from_le_bytes([c[0], c[1]])).collect();
        if let Some(&t) = tokens.iter().find(|&&t| t as usize >= vocab) {
            bail!("token {t} out of vocab {vocab}");
        }
        Ok(Corpus { vocab, tokens })
    }

    /// (train, eval) views: eval is the final 5 % (mirror of python).
    pub fn split(&self) -> (&[u16], &[u16]) {
        let n_eval = (self.tokens.len() / 20).max(1);
        self.tokens.split_at(self.tokens.len() - n_eval)
    }
}

/// Deterministic random-window batch sampler over a token slice.
pub struct Sampler<'a> {
    data: &'a [u16],
    rng: Prng,
}

impl<'a> Sampler<'a> {
    pub fn new(data: &'a [u16], seed: u64) -> Self {
        Sampler { data, rng: Prng::new(seed) }
    }

    /// Draw a `(batch, seq)` next-token batch from random windows.
    pub fn next_batch(&mut self, batch: usize, seq: usize) -> Batch {
        assert!(self.data.len() > seq + 1, "corpus shorter than sequence length");
        let mut tokens = Vec::with_capacity(batch * seq);
        let mut targets = Vec::with_capacity(batch * seq);
        for _ in 0..batch {
            let start = self.rng.below(self.data.len() - seq - 1);
            for i in 0..seq {
                tokens.push(self.data[start + i] as i32);
                targets.push(self.data[start + i + 1] as i32);
            }
        }
        Batch { tokens, targets, batch, seq }
    }

    /// Sequential (deterministic) eval batches covering the slice once.
    pub fn eval_batches(data: &'a [u16], batch: usize, seq: usize) -> Vec<Batch> {
        let window = batch * seq;
        let mut out = Vec::new();
        let mut pos = 0;
        while pos + window + 1 <= data.len() {
            let mut tokens = Vec::with_capacity(window);
            let mut targets = Vec::with_capacity(window);
            for i in 0..window {
                tokens.push(data[pos + i] as i32);
                targets.push(data[pos + i + 1] as i32);
            }
            out.push(Batch { tokens, targets, batch, seq });
            pos += window;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_corpus(n: usize, vocab: usize) -> Corpus {
        let tokens = (0..n).map(|i| (i % vocab) as u16).collect();
        Corpus { vocab, tokens }
    }

    #[test]
    fn loads_built_corpus_if_present() {
        let p = crate::runtime::default_artifacts_dir().join("corpus_v2048.bin");
        if !p.exists() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let c = Corpus::load(p).unwrap();
        assert_eq!(c.vocab, 2048);
        assert_eq!(c.tokens.len(), 600_000);
        let (train, eval) = c.split();
        assert_eq!(eval.len(), 30_000);
        assert_eq!(train.len() + eval.len(), 600_000);
    }

    #[test]
    fn sampler_is_deterministic_and_shifted() {
        let c = fake_corpus(10_000, 97);
        let (train, _) = c.split();
        let mut s1 = Sampler::new(train, 7);
        let mut s2 = Sampler::new(train, 7);
        let (a, b) = (s1.next_batch(2, 16), s2.next_batch(2, 16));
        assert_eq!(a.tokens, b.tokens);
        // Targets are tokens shifted by one.
        for i in 0..a.tokens.len() - 1 {
            if (i + 1) % 16 != 0 {
                assert_eq!(a.targets[i], a.tokens[i + 1]);
            }
        }
        let c2 = Sampler::new(train, 8).next_batch(2, 16);
        assert_ne!(a.tokens, c2.tokens, "different seed, different batch");
    }

    #[test]
    fn eval_batches_cover_sequentially() {
        let c = fake_corpus(1000, 13);
        let (_, eval) = c.split();
        let batches = Sampler::eval_batches(eval, 1, 8);
        assert!(!batches.is_empty());
        assert_eq!(batches[0].tokens.len(), 8);
        // First eval token is where the split starts.
        assert_eq!(batches[0].tokens[0], eval[0] as i32);
    }
}
