//! Model hosting: configuration (mirrored from the manifest), the weight
//! store with TP sharding, and the corpus/batch machinery. Everything the
//! coordinator needs to own a model without Python.

pub mod config;
pub mod corpus;
pub mod weights;

pub use config::ModelConfig;
pub use corpus::{Batch, Corpus, Sampler};
pub use weights::{shard_param, Weights};
