//! Model configuration, mirrored from `python/compile/model.py` via the
//! artifact manifest (single source of truth is the python side; rust reads
//! what was actually lowered).

use anyhow::Result;

use crate::runtime::manifest::Record;

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelConfig {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub seq_len: usize,
    pub n_experts: usize,
    pub d_expert: usize,
    pub moe_every: usize,
    /// TP width the shard pieces were lowered for.
    pub tp: usize,
    pub eval_batch: usize,
    pub train_batch: usize,
    /// Fixed expert capacity (tokens per expert batch) for EP inference.
    pub capacity: usize,
    pub n_params: usize,
}

impl ModelConfig {
    pub fn from_record(rec: &Record) -> Result<ModelConfig> {
        Ok(ModelConfig {
            name: rec.name.clone(),
            vocab: rec.get_usize("vocab")?,
            d_model: rec.get_usize("d_model")?,
            n_layers: rec.get_usize("n_layers")?,
            n_heads: rec.get_usize("n_heads")?,
            d_ff: rec.get_usize("d_ff")?,
            seq_len: rec.get_usize("seq_len")?,
            n_experts: rec.get_usize("n_experts")?,
            d_expert: rec.get_usize("d_expert")?,
            moe_every: rec.get_usize("moe_every")?,
            tp: rec.get_usize("tp")?,
            eval_batch: rec.get_usize("eval_batch")?,
            train_batch: rec.get_usize("train_batch")?,
            capacity: rec.get_usize("capacity")?,
            n_params: rec.get_usize("n_params")?,
        })
    }

    /// Is layer `l`'s FFN a mixture of experts? (mirror of python)
    pub fn is_moe_layer(&self, l: usize) -> bool {
        self.n_experts > 0 && l % self.moe_every == 1
    }

    /// Ordered parameter names — must match python `param_specs()`.
    pub fn param_names(&self) -> Vec<String> {
        let mut names = vec!["embed".to_string()];
        for l in 0..self.n_layers {
            for base in ["ln1_g", "ln1_b", "wq", "wk", "wv", "wo", "ln2_g", "ln2_b"] {
                names.push(format!("l{l}.{base}"));
            }
            if self.is_moe_layer(l) {
                for base in ["router", "we1", "we2"] {
                    names.push(format!("l{l}.{base}"));
                }
            } else {
                names.push(format!("l{l}.w1"));
                names.push(format!("l{l}.w2"));
            }
        }
        names.push("lnf_g".to_string());
        names.push("lnf_b".to_string());
        names
    }

    /// Artifact name helper.
    pub fn art(&self, piece: &str) -> String {
        format!("{}_{piece}", self.name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::Manifest;

    fn tiny() -> ModelConfig {
        let m = Manifest::parse(
            "config tiny vocab=2048 d_model=256 n_layers=4 n_heads=8 d_ff=1024 \
             seq_len=128 n_experts=0 d_expert=512 moe_every=2 tp=4 eval_batch=4 \
             train_batch=4 capacity=128 n_params=3674624",
        )
        .unwrap();
        ModelConfig::from_record(m.config("tiny").unwrap()).unwrap()
    }

    #[test]
    fn param_names_match_python_layout() {
        let cfg = tiny();
        let names = cfg.param_names();
        // 1 embed + 4 layers x 10 + 2 final = 43 (matches python specs).
        assert_eq!(names.len(), 43);
        assert_eq!(names[0], "embed");
        assert_eq!(names[1], "l0.ln1_g");
        assert_eq!(names[9], "l0.w1");
        assert_eq!(names[names.len() - 1], "lnf_b");
    }

    #[test]
    fn moe_layers_alternate() {
        let mut cfg = tiny();
        cfg.n_experts = 8;
        assert!(!cfg.is_moe_layer(0));
        assert!(cfg.is_moe_layer(1));
        assert!(!cfg.is_moe_layer(2));
        assert!(cfg.is_moe_layer(3));
        let names = cfg.param_names();
        assert!(names.contains(&"l1.router".to_string()));
        assert!(names.contains(&"l0.w1".to_string()));
        assert!(!names.contains(&"l1.w1".to_string()));
    }
}
