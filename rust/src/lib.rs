//! # FlashCommunication V2 — reproduction
//!
//! A three-layer Rust + JAX + Pallas implementation of *FlashCommunication
//! V2: Bit Splitting and Spike Reserving for Any Bit Communication*
//! (Li et al., 2025).
//!
//! - [`quant`] — any-bit quantization: RTN, bit splitting, spike reserving,
//!   Hadamard/LogFMT baselines, wire format.
//! - [`transport`] — pluggable point-to-point fabric with a versioned,
//!   CRC-guarded frame protocol: in-process mpsc mesh, multi-process TCP
//!   (rendezvous bootstrap), single-rank loopback.
//! - [`session`] — the session fabric over the transports: per-peer
//!   heartbeats and receive deadlines (`Healthy → Suspect → Lost`), a
//!   frame-carried session epoch so restarted ranks rejoin without
//!   poisoning seq spaces, degraded-mode membership
//!   ([`session::DegradedMesh`] + [`session::survivor_topology`]) for
//!   re-planning over the survivors, and a deterministic
//!   [`session::FaultInjector`] for failure testing.
//! - [`comm`] — the collective layer behind one front door,
//!   [`comm::Communicator`]: fallible `allreduce` / `reduce_scatter` /
//!   `all_gather` / `broadcast` / `all2all` methods (typed
//!   [`comm::CommError`]), per-call algorithm selection via
//!   [`comm::AlgoPolicy`] (`Auto` consults the cost model), persistent
//!   scratch, generic over the transport.
//! - [`plan`] — the communication plan compiler: a typed
//!   [`plan::CommPlan`] (algorithm, per-link-tier stage codecs, chunk
//!   count, send window, thread budget) searched over admissible
//!   candidates, priced by the sim, and cached in an LRU keyed by
//!   topology fingerprint so the hot path compiles once.
//! - [`topo`] / [`sim`] — device topology presets (Table 6) and the link
//!   simulator producing algorithmic-bandwidth estimates (Tables 5, 9, 10)
//!   that also powers `AlgoPolicy::Auto` and the plan compiler.
//! - [`telemetry`] — the flight recorder (lock-free per-rank event ring),
//!   the metrics registry (one snapshot/export path for spans, byte
//!   counters, and plan-cache statistics), and the trace→profile
//!   distillation behind profile-guided plan recalibration.
//! - [`lint`] — flashlint, the repo-native static-analysis pass: five
//!   rules (wire-constant drift, panic paths, lock discipline, unsafe
//!   audit, observability completeness) over comment/string-aware lexed
//!   source; `flashcomm lint` gates CI (DESIGN.md §14).
//! - [`runtime`] — PJRT CPU client wrapper loading AOT HLO artifacts.
//! - [`model`] — weights/tokenizer/corpus/checkpoint handling.
//! - [`coordinator`] — TP inference engine, DP trainer, EP dispatcher, TTFT
//!   model: the request-path orchestration, Python-free.
//!
//! See DESIGN.md for the full system inventory and EXPERIMENTS.md for the
//! paper-vs-measured record.

pub mod cli;
pub mod comm;
pub mod coordinator;
pub mod harness;
pub mod lint;
pub mod model;
pub mod plan;
pub mod quant;
pub mod runtime;
pub mod session;
pub mod sim;
pub mod telemetry;
pub mod topo;
pub mod transport;
pub mod util;
