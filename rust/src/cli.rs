//! Minimal CLI argument parser (no clap in the offline vendor set).
//!
//! Grammar: `flashcomm <command> [positional...] [--flag value] [--switch]`.
//! A flag is a `--name` followed by a value unless it is a known boolean
//! switch or the next token is another flag.

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub command: String,
    pub positional: Vec<String>,
    pub flags: HashMap<String, String>,
}

impl Args {
    /// Parse from an iterator of tokens (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> Result<Args> {
        let mut args = Args::default();
        let mut it = tokens.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                let is_flag_next = it.peek().map(|n| n.starts_with("--")).unwrap_or(true);
                let value =
                    if is_flag_next { "true".to_string() } else { it.next().unwrap() };
                args.flags.insert(name.to_string(), value);
            } else if args.command.is_empty() {
                args.command = tok;
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn flag_or(&self, name: &str, default: &str) -> String {
        self.flag(name).unwrap_or(default).to_string()
    }

    pub fn flag_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{name} {v}")),
        }
    }

    pub fn flag_bool(&self, name: &str) -> bool {
        matches!(self.flag(name), Some("true") | Some("1") | Some("yes"))
    }

    pub fn pos(&self, i: usize) -> Result<&str> {
        self.positional
            .get(i)
            .map(|s| s.as_str())
            .with_context(|| format!("missing positional argument {i}"))
    }

    pub fn require(&self, name: &str) -> Result<&str> {
        match self.flag(name) {
            Some(v) => Ok(v),
            None => bail!("missing required flag --{name}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn command_positionals_flags() {
        let a = parse("table 9 --size 64M --quick --codec int5");
        assert_eq!(a.command, "table");
        assert_eq!(a.pos(0).unwrap(), "9");
        assert_eq!(a.flag("size"), Some("64M"));
        assert!(a.flag_bool("quick"));
        assert_eq!(a.flag("codec"), Some("int5"));
        assert!(a.pos(1).is_err());
    }

    #[test]
    fn trailing_switch_is_boolean() {
        let a = parse("train --steps 100 --verbose");
        assert_eq!(a.flag_usize("steps", 0).unwrap(), 100);
        assert!(a.flag_bool("verbose"));
        assert!(!a.flag_bool("missing"));
    }

    #[test]
    fn defaults() {
        let a = parse("eval");
        assert_eq!(a.flag_or("config", "tiny"), "tiny");
        assert_eq!(a.flag_usize("steps", 7).unwrap(), 7);
        assert!(a.require("codec").is_err());
    }
}
