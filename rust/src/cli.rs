//! Minimal CLI argument parser (no clap in the offline vendor set).
//!
//! Grammar: `flashcomm <command> [positional...] [--flag value] [--switch]`.
//! A flag is a `--name` followed by a value unless it is a known boolean
//! switch or the next token is another flag.

use std::collections::HashMap;

use anyhow::{bail, ensure, Context, Result};

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub command: String,
    pub positional: Vec<String>,
    pub flags: HashMap<String, String>,
}

impl Args {
    /// Parse from an iterator of tokens (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> Result<Args> {
        let mut args = Args::default();
        let mut it = tokens.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                let is_flag_next = it.peek().map(|n| n.starts_with("--")).unwrap_or(true);
                let value =
                    if is_flag_next { "true".to_string() } else { it.next().unwrap() };
                args.flags.insert(name.to_string(), value);
            } else if args.command.is_empty() {
                args.command = tok;
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn flag_or(&self, name: &str, default: &str) -> String {
        self.flag(name).unwrap_or(default).to_string()
    }

    pub fn flag_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{name} {v}")),
        }
    }

    pub fn flag_bool(&self, name: &str) -> bool {
        matches!(self.flag(name), Some("true") | Some("1") | Some("yes"))
    }

    pub fn pos(&self, i: usize) -> Result<&str> {
        self.positional
            .get(i)
            .map(|s| s.as_str())
            .with_context(|| format!("missing positional argument {i}"))
    }

    pub fn require(&self, name: &str) -> Result<&str> {
        match self.flag(name) {
            Some(v) => Ok(v),
            None => bail!("missing required flag --{name}"),
        }
    }
}

/// Transport backend selection (`--transport inproc|tcp|udp`), shared by
/// every fabric-driving command and bench so the flag is spelled — and
/// rejected — identically everywhere. Commands declare which backends
/// they support via [`transport_flag`]; a valid-but-unsupported backend
/// is a loud typed error, never a silent fallback.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransportSel {
    /// In-process channel mesh (the bit-exact reference backend).
    InProc,
    /// TCP stream mesh with per-link framing (DESIGN.md §4).
    Tcp,
    /// Loss-tolerant UDP datagram mesh with NACK recovery (DESIGN.md §13).
    Udp,
}

impl TransportSel {
    pub fn parse(s: &str) -> Result<TransportSel> {
        match s.to_ascii_lowercase().as_str() {
            "inproc" => Ok(TransportSel::InProc),
            "tcp" => Ok(TransportSel::Tcp),
            "udp" => Ok(TransportSel::Udp),
            other => bail!("--transport {other}: expected inproc, tcp, or udp"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            TransportSel::InProc => "inproc",
            TransportSel::Tcp => "tcp",
            TransportSel::Udp => "udp",
        }
    }
}

impl std::fmt::Display for TransportSel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Resolve `--transport` against the backends this command supports
/// (`allowed[0]` is the default when the flag is absent).
pub fn transport_flag(args: &Args, allowed: &[TransportSel]) -> Result<TransportSel> {
    let sel = match args.flag("transport") {
        None => allowed[0],
        Some(v) => TransportSel::parse(v)?,
    };
    ensure!(
        allowed.contains(&sel),
        "--transport {sel} is not supported here (supported: {})",
        allowed.iter().map(|t| t.name()).collect::<Vec<_>>().join(", ")
    );
    Ok(sel)
}

/// A seeded wire-fault program parsed from the chaos knobs
/// (`--wire-fault-pct P [--wire-fault-seed S]`): every datagram is
/// dropped / duplicated / corrupted / reordered with probability
/// `rate = P / 100` each, deterministically from `seed` (per-rank salts
/// are applied by the caller). The knobs only mean something on the UDP
/// datagram backend, so any other selection rejects them loudly — a
/// "chaos run" that silently injected nothing would be a false green.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WireFaultSpec {
    pub seed: u64,
    pub rate: f64,
}

/// Parse the wire-fault knob pair for the selected transport. `None`
/// when neither knob was given; a typed error when they are given on a
/// non-UDP backend or malformed.
pub fn wire_fault_flags(args: &Args, sel: TransportSel) -> Result<Option<WireFaultSpec>> {
    let pct = args.flag("wire-fault-pct");
    let seed = args.flag("wire-fault-seed");
    if pct.is_none() && seed.is_none() {
        return Ok(None);
    }
    ensure!(
        sel == TransportSel::Udp,
        "--wire-fault-pct / --wire-fault-seed inject datagram loss and only apply to \
         --transport udp (got --transport {sel}); refusing to run a chaos drill that \
         injects nothing"
    );
    let pct = pct.context("--wire-fault-seed without --wire-fault-pct injects nothing")?;
    let rate: f64 = pct.parse::<f64>().with_context(|| format!("--wire-fault-pct {pct}"))? / 100.0;
    ensure!(
        rate > 0.0 && rate < 1.0,
        "--wire-fault-pct {pct}: expected a percentage in (0, 100)"
    );
    let seed: u64 = match seed {
        None => 0x5EED_FA11,
        Some(v) => v.parse().with_context(|| format!("--wire-fault-seed {v}"))?,
    };
    Ok(Some(WireFaultSpec { seed, rate }))
}

/// Resolve `--trace-capacity` (events per rank in the telemetry ring;
/// defaults to [`crate::telemetry::Recorder`]'s built-in capacity). Zero
/// is rejected loudly: a zero-slot ring records nothing and every span
/// the run emits would silently count as dropped.
pub fn trace_capacity_flag(args: &Args) -> Result<usize> {
    let cap = args.flag_usize("trace-capacity", crate::telemetry::DEFAULT_CAPACITY)?;
    ensure!(
        cap > 0,
        "--trace-capacity 0: a zero-slot trace ring drops every event; omit the flag \
         for the default ({})",
        crate::telemetry::DEFAULT_CAPACITY
    );
    Ok(cap)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn command_positionals_flags() {
        let a = parse("table 9 --size 64M --quick --codec int5");
        assert_eq!(a.command, "table");
        assert_eq!(a.pos(0).unwrap(), "9");
        assert_eq!(a.flag("size"), Some("64M"));
        assert!(a.flag_bool("quick"));
        assert_eq!(a.flag("codec"), Some("int5"));
        assert!(a.pos(1).is_err());
    }

    #[test]
    fn trailing_switch_is_boolean() {
        let a = parse("train --steps 100 --verbose");
        assert_eq!(a.flag_usize("steps", 0).unwrap(), 100);
        assert!(a.flag_bool("verbose"));
        assert!(!a.flag_bool("missing"));
    }

    #[test]
    fn defaults() {
        let a = parse("eval");
        assert_eq!(a.flag_or("config", "tiny"), "tiny");
        assert_eq!(a.flag_usize("steps", 7).unwrap(), 7);
        assert!(a.require("codec").is_err());
    }

    #[test]
    fn transport_flag_defaults_parses_and_rejects() {
        let all = [TransportSel::InProc, TransportSel::Tcp, TransportSel::Udp];
        // Absent flag -> the command's default (first allowed entry).
        let sel = transport_flag(&parse("worker"), &[TransportSel::Tcp]).unwrap();
        assert_eq!(sel, TransportSel::Tcp);
        // Explicit selections parse case-insensitively.
        let sel = transport_flag(&parse("worker --transport UDP"), &all).unwrap();
        assert_eq!(sel, TransportSel::Udp);
        let sel = transport_flag(&parse("bench --transport inproc"), &all).unwrap();
        assert_eq!(sel, TransportSel::InProc);
        // Unknown backend: parse error naming the token.
        let err = transport_flag(&parse("worker --transport carrier-pigeon"), &all).unwrap_err();
        assert!(err.to_string().contains("carrier-pigeon"), "{err}");
        // Valid backend a command does not support: loud, lists what is.
        let err =
            transport_flag(&parse("train --transport udp"), &[TransportSel::InProc]).unwrap_err();
        assert!(err.to_string().contains("not supported"), "{err}");
        assert!(err.to_string().contains("inproc"), "{err}");
    }

    #[test]
    fn trace_capacity_defaults_parses_and_rejects_zero() {
        let cap = trace_capacity_flag(&parse("worker")).unwrap();
        assert_eq!(cap, crate::telemetry::DEFAULT_CAPACITY);
        let cap = trace_capacity_flag(&parse("worker --trace-capacity 128")).unwrap();
        assert_eq!(cap, 128);
        let err = trace_capacity_flag(&parse("worker --trace-capacity 0")).unwrap_err();
        assert!(err.to_string().contains("zero-slot"), "{err}");
        assert!(trace_capacity_flag(&parse("worker --trace-capacity lots")).is_err());
    }

    #[test]
    fn wire_fault_knobs_are_udp_only_and_never_a_silent_noop() {
        // Absent knobs: no fault program on any backend.
        assert_eq!(wire_fault_flags(&parse("worker"), TransportSel::Tcp).unwrap(), None);
        // Present on UDP: parsed, percentage scaled to a rate.
        let args = parse("worker --wire-fault-pct 5 --wire-fault-seed 42");
        let f = wire_fault_flags(&args, TransportSel::Udp).unwrap().unwrap();
        assert_eq!(f.seed, 42);
        assert!((f.rate - 0.05).abs() < 1e-12);
        // Seed defaults when only the rate is pinned.
        let f = wire_fault_flags(&parse("worker --wire-fault-pct 1"), TransportSel::Udp)
            .unwrap()
            .unwrap();
        assert!((f.rate - 0.01).abs() < 1e-12);
        // Present on a non-UDP backend: loud typed error, not a no-op.
        for sel in [TransportSel::InProc, TransportSel::Tcp] {
            let err = wire_fault_flags(&parse("worker --wire-fault-pct 5"), sel).unwrap_err();
            assert!(err.to_string().contains("only apply to --transport udp"), "{err}");
        }
        // A lone seed injects nothing — also rejected.
        let err =
            wire_fault_flags(&parse("worker --wire-fault-seed 9"), TransportSel::Udp).unwrap_err();
        assert!(err.to_string().contains("injects nothing"), "{err}");
        // Rate bounds: 0 and 100 are refused (WireFault asserts rate < 1).
        for bad in ["0", "100", "-3"] {
            let args = parse(&format!("worker --wire-fault-pct {bad}"));
            assert!(wire_fault_flags(&args, TransportSel::Udp).is_err(), "pct {bad} accepted");
        }
    }
}
