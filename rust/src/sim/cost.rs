//! QDQ cost model: how long quantize/dequantize takes on a device.
//!
//! The paper's fused kernel burns `comm_sms` SMs on compression (48 of
//! them, all 78 on H20) — this tax is why INT2 stops winning on
//! high-bandwidth/low-compute devices (Table 9, H20 column). We model the
//! kernel as a number of *element passes* (one pass = touch every element
//! once) per codec, with per-device pass rates calibrated in
//! `topo::presets`.
//!
//! Pass counts are relative costs mirroring the measured Rust hot path
//! (`cargo bench quant`): RTN encode is a min/max pass plus a quantize
//! pass plus per-plane packing; spike reserving adds an argmin/argmax +
//! second-extrema pass; Hadamard adds log2(gs) butterfly passes each way;
//! LogFMT pays for log/exp transcendentals.

use crate::quant::{scheme::Codec, spike::ScaleMode};
use crate::topo::GpuSpec;

/// Element passes for one encode / decode / fused reduce of a codec.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CodecCost {
    pub encode_passes: f64,
    pub decode_passes: f64,
    /// Extra passes for a fused dequantize-accumulate (reduce) step.
    pub reduce_passes: f64,
}

/// Packing cost per bit plane (a byte-shuffle pass is far cheaper than an
/// arithmetic pass over f32s).
const PACK_PASS_PER_PLANE: f64 = 0.6;

/// Cost model for a codec.
pub fn codec_cost(codec: &Codec) -> CodecCost {
    match *codec {
        Codec::Bf16 => CodecCost { encode_passes: 0.25, decode_passes: 0.25, reduce_passes: 0.5 },
        Codec::Rtn { bits, scale_mode, .. } => {
            let planes = crate::quant::bitsplit::planes_for(bits).len() as f64;
            let meta = if scale_mode == ScaleMode::IntLog { 0.1 } else { 0.0 };
            CodecCost {
                encode_passes: 2.0 + PACK_PASS_PER_PLANE * planes + meta,
                decode_passes: 1.0 + PACK_PASS_PER_PLANE * planes + meta,
                reduce_passes: 0.5,
            }
        }
        Codec::Spike { bits, scale_mode, .. } => {
            let planes = crate::quant::bitsplit::planes_for(bits).len() as f64;
            let meta = if scale_mode == ScaleMode::IntLog { 0.1 } else { 0.0 };
            CodecCost {
                // + argmin/argmax pass, shrunken-range re-scan, and the
                // spike scatter/gather + index metadata handling that the
                // paper pays vectorized warps for (Table 9: INT2_SR trails
                // INT3 on every NVLink device).
                encode_passes: 4.5 + PACK_PASS_PER_PLANE * planes + meta,
                decode_passes: 2.5 + PACK_PASS_PER_PLANE * planes + meta,
                reduce_passes: 0.5,
            }
        }
        Codec::Hadamard { bits, group_size } => {
            let planes = crate::quant::bitsplit::planes_for(bits).len() as f64;
            let fwht = (group_size as f64).log2() * 0.5;
            CodecCost {
                encode_passes: 2.0 + fwht + PACK_PASS_PER_PLANE * planes,
                decode_passes: 1.0 + fwht + PACK_PASS_PER_PLANE * planes,
                reduce_passes: 0.5,
            }
        }
        Codec::LogFmt { bits, .. } => {
            let planes = crate::quant::bitsplit::planes_for(bits).len() as f64;
            // log2/exp2 transcendentals dominate (CUDA Math API footnote).
            CodecCost {
                encode_passes: 4.0 + PACK_PASS_PER_PLANE * planes,
                decode_passes: 3.0 + PACK_PASS_PER_PLANE * planes,
                reduce_passes: 0.5,
            }
        }
    }
}

/// Time (s) for `passes` element-passes over `elems` elements on `spec`.
pub fn pass_time(spec: &GpuSpec, elems: f64, passes: f64) -> f64 {
    elems * passes / spec.qdq_pass_rate
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topo::presets;

    fn c(spec: &str) -> Codec {
        Codec::parse(spec).unwrap()
    }

    #[test]
    fn sr_costs_more_than_rtn() {
        let rtn = codec_cost(&c("int2@32"));
        let sr = codec_cost(&c("int2-sr@32"));
        assert!(sr.encode_passes > rtn.encode_passes);
    }

    #[test]
    fn baselines_cost_more_than_rtn() {
        let rtn = codec_cost(&c("int4@32"));
        assert!(codec_cost(&c("int4-had@32")).encode_passes > rtn.encode_passes);
        assert!(codec_cost(&c("int4-log@32")).encode_passes > rtn.encode_passes);
    }

    #[test]
    fn more_planes_cost_more() {
        // INT7 = 3 planes vs INT4 = 1 plane.
        assert!(
            codec_cost(&c("int7")).encode_passes > codec_cost(&c("int4")).encode_passes
        );
    }

    #[test]
    fn bf16_passthrough_is_cheapest() {
        let bf = codec_cost(&Codec::Bf16);
        assert!(bf.encode_passes < codec_cost(&c("int8")).encode_passes);
    }

    #[test]
    fn pass_time_scales() {
        let spec = presets::h800();
        let t1 = pass_time(&spec, 1e6, 2.0);
        let t2 = pass_time(&spec, 2e6, 2.0);
        assert!((t2 / t1 - 2.0).abs() < 1e-9);
    }
}
