//! Measured link/compute profiles for profile-guided plan recalibration.
//!
//! The cost model prices plan candidates from static calibration constants
//! ([`crate::topo::GpuSpec`]). On a real deployment those constants can be
//! wrong — a mis-seated bridge, a congested inter-node fabric, a QDQ
//! kernel running slower than calibrated — and the compiler would keep
//! picking the plan the *datasheet* likes. A [`MeasuredProfile`] carries
//! effective rates distilled from flight-recorder traces
//! ([`crate::telemetry::distill_profile`]); applying it to a topology
//! overrides exactly the terms the simulator prices
//! ([`crate::topo::Topology::recalibrated`]), so
//! `plan::compile_profiled` re-ranks candidates against what the fabric
//! actually delivers. See DESIGN.md §11 for the distillation formula.

use crate::topo::Topology;

/// Effective rates measured from a live run. Every field is optional: a
/// profile only overrides what it measured, and non-finite or non-positive
/// measurements are ignored ([`MeasuredProfile::apply`] sanitizes), so a
/// degenerate trace can never poison the plan compiler.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct MeasuredProfile {
    /// Effective intra-group link bandwidth, bytes/s.
    pub intra_bw: Option<f64>,
    /// Effective inter-group link bandwidth, bytes/s.
    pub inter_bw: Option<f64>,
    /// Effective QDQ throughput, element-passes/s (the unit of
    /// [`crate::topo::GpuSpec::qdq_pass_rate`]).
    pub qdq_pass_rate: Option<f64>,
}

fn sane(v: Option<f64>) -> Option<f64> {
    v.filter(|x| x.is_finite() && *x > 0.0)
}

impl MeasuredProfile {
    /// True when no field would override anything.
    pub fn is_empty(&self) -> bool {
        sane(self.intra_bw).is_none()
            && sane(self.inter_bw).is_none()
            && sane(self.qdq_pass_rate).is_none()
    }

    /// The recalibrated topology: `topo` with every measured (and sane)
    /// rate substituted for its static counterpart. The result has a
    /// different [`Topology::fingerprint`] whenever anything changed, so
    /// plan-cache entries keyed on the static topology are never reused
    /// for profiled compilations.
    pub fn apply(&self, topo: &Topology) -> Topology {
        topo.recalibrated(sane(self.intra_bw), sane(self.inter_bw), sane(self.qdq_pass_rate))
    }

    /// Human-readable one-liner for log output.
    pub fn summary(&self) -> String {
        let gb = |v: Option<f64>| match sane(v) {
            Some(x) => format!("{:.2} GB/s", x / 1e9),
            None => "-".into(),
        };
        let passes = match sane(self.qdq_pass_rate) {
            Some(x) => format!("{:.2} Gpass/s", x / 1e9),
            None => "-".into(),
        };
        format!("intra={} inter={} qdq={}", gb(self.intra_bw), gb(self.inter_bw), passes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topo::presets::{h800, l40};

    #[test]
    fn empty_profile_is_identity() {
        let topo = Topology::new(l40(), 8);
        let p = MeasuredProfile::default();
        assert!(p.is_empty());
        assert_eq!(p.apply(&topo), topo);
        assert_eq!(p.apply(&topo).fingerprint(), topo.fingerprint());
    }

    #[test]
    fn overrides_change_only_the_measured_terms() {
        let topo = Topology::new(l40(), 8);
        let p = MeasuredProfile { inter_bw: Some(5e9), ..Default::default() };
        let t = p.apply(&topo);
        assert_eq!(t.inter_bw(), Some(5e9));
        assert_eq!(t.spec.intra_bw(), topo.spec.intra_bw());
        assert_eq!(t.spec.qdq_pass_rate, topo.spec.qdq_pass_rate);
        assert_ne!(t.fingerprint(), topo.fingerprint(), "recalibration re-keys the plan cache");
    }

    #[test]
    fn insane_measurements_are_ignored() {
        let topo = Topology::new(h800(), 8);
        for bad in [f64::NAN, f64::INFINITY, 0.0, -3.0] {
            let p = MeasuredProfile {
                intra_bw: Some(bad),
                inter_bw: Some(bad),
                qdq_pass_rate: Some(bad),
            };
            assert!(p.is_empty());
            assert_eq!(p.apply(&topo), topo);
        }
    }

    #[test]
    fn flat_topologies_never_grow_an_inter_link() {
        let topo = Topology::new(h800(), 8);
        let p = MeasuredProfile { inter_bw: Some(9e9), ..Default::default() };
        assert_eq!(p.apply(&topo).inter_bw(), None);
    }

    #[test]
    fn summary_reads_like_a_log_line() {
        let p = MeasuredProfile { intra_bw: Some(24e9), ..Default::default() };
        let s = p.summary();
        assert!(s.contains("intra=24.00 GB/s"), "{s}");
        assert!(s.contains("inter=-"), "{s}");
    }
}
