//! All2All (expert-parallel dispatch) timing model (Table 10).
//!
//! Following DeepSeek-V3 (and the paper), only the *dispatch* direction is
//! quantized; the combine direction stays BF16. Each GPU scatters M/N bytes
//! to each of the other N-1 ranks. There is no reduction, so QDQ is a
//! single encode on the sender and a single decode on the receiver.

use super::cost::{codec_cost, pass_time};
use crate::quant::Codec;
use crate::topo::{Interconnect, Topology};

use super::allreduce::TimeBreakdown;

/// Time one quantized-dispatch All2All of `m_bytes` (BF16 bytes per GPU).
pub fn all2all_time(topo: &Topology, codec: &Codec, m_bytes: f64) -> TimeBreakdown {
    let n = topo.n_gpus as f64;
    let elems = m_bytes / 2.0;
    let ratio = codec.compression_ratio(elems as usize);
    let spec = &topo.spec;
    let cost = codec_cost(codec);
    let outbound = (n - 1.0) / n * m_bytes * ratio;
    let intra = match spec.interconnect {
        Interconnect::NvLink { .. } => outbound / (spec.intra_bw() * spec.a2a_eff),
        Interconnect::PcieNuma { .. } => outbound / spec.intra_bw(),
    };
    let transfer = match topo.inter_bw() {
        // (N−s)/N of each GPU's traffic leaves its group, balanced over
        // the inter-group links (the shared sim::volume link model). At
        // G=2 this is the "half the destinations are across the bridge"
        // accounting: N·(s/N)·M.
        Some(bw) => {
            let s = topo.group_size() as f64;
            let cross =
                (n - s) * m_bytes * ratio / super::volume::inter_group_links(topo.numa_groups);
            (cross / bw).max(intra)
        }
        None => intra,
    };
    let enc = elems * cost.encode_passes;
    let dec = elems * (n - 1.0) / n * cost.decode_passes;
    let qdq =
        if matches!(codec, Codec::Bf16) { 0.0 } else { pass_time(spec, 1.0, enc + dec) };
    TimeBreakdown { transfer_s: transfer, qdq_s: qdq, latency_s: spec.stage_latency_s }
}

/// Algorithmic bandwidth for the dispatch (GB/s).
pub fn algbw_gbps(m_bytes: f64, t: &TimeBreakdown) -> f64 {
    m_bytes / t.total() / 1e9
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topo::{presets, Topology};

    fn c(s: &str) -> Codec {
        Codec::parse(s).unwrap()
    }

    const M: f64 = 64.0 * 1024.0 * 1024.0;

    #[test]
    fn h800_int4_best_and_near_2x() {
        // Table 10: on H800, INT4 is the best bitwidth at ~2.01x BF16.
        let topo = Topology::new(presets::h800(), 8);
        let bf = algbw_gbps(M, &all2all_time(&topo, &Codec::Bf16, M));
        let mut best = ("bf16", bf);
        for s in ["int8", "int6", "int5", "int4@32", "int3@32", "int2-sr@32"] {
            let bw = algbw_gbps(M, &all2all_time(&topo, &c(s), M));
            if bw > best.1 {
                best = (s, bw);
            }
        }
        assert_eq!(best.0, "int4@32", "best scheme");
        let speedup = best.1 / bf;
        assert!((1.5..=2.5).contains(&speedup), "H800 INT4 speedup {speedup}");
    }

    #[test]
    fn h20_sees_no_benefit() {
        // Table 10 / paper: "no benefit in the high-bandwidth system as H20".
        let topo = Topology::new(presets::h20(), 8);
        let bf = algbw_gbps(M, &all2all_time(&topo, &Codec::Bf16, M));
        for s in ["int2-sr@32", "int3@32"] {
            let bw = algbw_gbps(M, &all2all_time(&topo, &c(s), M));
            assert!(bw < bf * 1.35, "{s}: {bw} vs bf16 {bf} should show little gain");
        }
        let int2 = algbw_gbps(M, &all2all_time(&topo, &c("int2-sr@32"), M));
        let int4 = algbw_gbps(M, &all2all_time(&topo, &c("int4@32"), M));
        assert!(int2 < int4, "INT2_SR must lose to INT4 on H20");
    }

    #[test]
    fn no_reduce_passes_charged() {
        // All2All has no reduction: its QDQ must be cheaper than the same
        // codec's two-step AllReduce QDQ.
        let topo = Topology::new(presets::a100(), 8);
        let a2a = all2all_time(&topo, &c("int8"), M);
        let ar = super::super::allreduce::allreduce_time(
            &topo,
            super::super::volume::Algo::TwoStep,
            &c("int8"),
            M,
        );
        assert!(a2a.qdq_s < ar.qdq_s);
    }
}
