//! AllReduce timing model (Table 9).
//!
//! For each algorithm we account, per stage: the busiest-link transfer time
//! (volumes from [`super::volume`], compressed by the codec's wire ratio),
//! the QDQ compute time ([`super::cost`]), and per-stage launch latency.
//! The pipelined hierarchical variant builds a micro-chunk DAG and lets the
//! event scheduler ([`super::events`]) overlap bridge and PCIe traffic
//! (Fig. 8).
//!
//! "Algorithmic bandwidth" is the paper's metric: payload bytes per GPU
//! divided by wall time, in GB/s.

use super::cost::{codec_cost, pass_time};
use super::events::{schedule, serial_makespan, Task};
use super::volume::Algo;
use crate::plan::{CommPlan, StageCodecs};
use crate::quant::Codec;
use crate::topo::{Interconnect, Topology};

/// Where the time went.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct TimeBreakdown {
    pub transfer_s: f64,
    pub qdq_s: f64,
    pub latency_s: f64,
}

impl TimeBreakdown {
    pub fn total(&self) -> f64 {
        self.transfer_s + self.qdq_s + self.latency_s
    }
}

/// Algorithmic bandwidth in GB/s for `m_bytes` payload per GPU.
pub fn algbw_gbps(m_bytes: f64, t: &TimeBreakdown) -> f64 {
    m_bytes / t.total() / 1e9
}

/// Time an AllReduce of `m_bytes` (BF16 payload bytes per GPU).
pub fn allreduce_time(topo: &Topology, algo: Algo, codec: &Codec, m_bytes: f64) -> TimeBreakdown {
    let n = topo.n_gpus as f64;
    let elems = m_bytes / 2.0; // BF16 payload
    let ratio = codec.compression_ratio(elems as usize); // wire bytes / bf16 bytes
    let spec = &topo.spec;
    let cost = codec_cost(codec);
    let lat = spec.stage_latency_s;
    let groups = topo.numa_groups;

    match algo {
        Algo::Ring => {
            // NCCL baseline: RS + AG around the ring, 2(N-1) steps. The
            // paper only runs BF16 over NCCL; a quantized ring would QDQ at
            // every hop (kept here as the ablation `ring+codec`).
            let per_link = 2.0 * (n - 1.0) / n * m_bytes * ratio;
            let intra = match spec.interconnect {
                Interconnect::PcieNuma { .. } => per_link / spec.intra_bw(),
                Interconnect::NvLink { .. } => per_link / (spec.intra_bw() * spec.ring_eff),
            };
            // The slowest link bounds the ring: the inter-group link
            // carries the boundary-crossing volume (the paper's 7M/4).
            let transfer = match topo.inter_bw() {
                Some(bw) => {
                    let cross =
                        super::volume::cross_numa_volume(algo, topo.n_gpus, groups, m_bytes)
                            * ratio;
                    (cross / bw).max(intra)
                }
                None => intra,
            };
            // QDQ at every hop: 2(N-1) rounds over M/N-element chunks.
            let hops = 2.0 * (n - 1.0);
            let qdq = if matches!(codec, Codec::Bf16) {
                0.0
            } else {
                pass_time(
                    spec,
                    hops * elems / n,
                    cost.encode_passes + cost.decode_passes + cost.reduce_passes,
                )
            };
            TimeBreakdown { transfer_s: transfer, qdq_s: qdq, latency_s: hops * lat }
        }
        Algo::TwoStep => {
            // One-shot RS (+reduce) then one-shot AG, fused QDQ. The
            // busiest inter-group link carries its share of the all-to-all
            // cross traffic when the topology has one.
            let intra = 2.0 * (n - 1.0) / n * m_bytes * ratio / spec.intra_bw();
            let transfer = match topo.inter_bw() {
                Some(bw) => {
                    let cross =
                        super::volume::cross_numa_volume(algo, topo.n_gpus, groups, m_bytes)
                            * ratio;
                    (cross / bw).max(intra)
                }
                None => intra,
            };
            // Encode all own data + the reduced chunk; decode N-1 incoming
            // chunks with reduce, then N-1 gathered chunks plain.
            let enc = elems * (1.0 + 1.0 / n) * cost.encode_passes;
            let dec_red = elems * (n - 1.0) / n * (cost.decode_passes + cost.reduce_passes);
            let dec = elems * (n - 1.0) / n * cost.decode_passes;
            let qdq = pass_time(spec, 1.0, enc + dec_red + dec);
            TimeBreakdown { transfer_s: transfer, qdq_s: qdq, latency_s: 2.0 * lat }
        }
        Algo::Hier => {
            let b = hier_stage_times(topo, codec, m_bytes);
            // Two intra stages plus the (G−1)-hop leader ring.
            let cross_hops = (groups.max(2) - 1) as f64;
            TimeBreakdown {
                transfer_s: b.rs_intra + b.cross + b.ag_intra,
                qdq_s: b.qdq_total,
                latency_s: (2.0 + cross_hops) * lat,
            }
        }
        Algo::HierPipelined => {
            // Adaptive micro-chunking: per-chunk launch overhead eats the
            // overlap win on small payloads, so scale the chunk count with
            // the message size (the paper's kernel does the same by fixing
            // the chunk size, not the chunk count).
            let chunks = ((m_bytes / (8.0 * 1024.0 * 1024.0)) as usize).clamp(2, 8);
            hier_pipelined_time(topo, codec, m_bytes, chunks)
        }
    }
}

/// Time a full [`CommPlan`]: the pricing primitive of the plan compiler.
///
/// One-stage algorithms price through [`allreduce_time`] with the plan's
/// (uniform) codec; the hierarchical family prices each stage with *its*
/// codec ([`hier_stage_times_staged`]) — the pipelined variant builds the
/// micro-chunk DAG with the plan's own chunk count instead of the
/// size-adaptive default. A uniform plan with the default knobs prices
/// identically to `allreduce_time` for ring/twostep/hier (hierpp differs
/// only in the chunk count, which the plan makes explicit).
pub fn plan_time(topo: &Topology, plan: &CommPlan, m_bytes: f64) -> TimeBreakdown {
    match plan.algo {
        Algo::Ring | Algo::TwoStep => {
            allreduce_time(topo, plan.algo, &plan.stage_codecs.intra_rs, m_bytes)
        }
        Algo::Hier => {
            let b = hier_stage_times_staged(topo, &plan.stage_codecs, m_bytes);
            let cross_hops = (topo.numa_groups.max(2) - 1) as f64;
            TimeBreakdown {
                transfer_s: b.rs_intra + b.cross + b.ag_intra,
                qdq_s: b.qdq_total,
                latency_s: (2.0 + cross_hops) * topo.spec.stage_latency_s,
            }
        }
        Algo::HierPipelined => {
            hier_pipelined_time_staged(topo, &plan.stage_codecs, m_bytes, plan.chunks.max(1))
        }
    }
}

/// Per-stage transfer times of the hierarchical algorithm (Figs. 6–7).
#[derive(Debug, Clone, Copy)]
pub struct HierStages {
    pub rs_intra: f64,
    pub cross: f64,
    pub ag_intra: f64,
    pub qdq_total: f64,
}

pub fn hier_stage_times(topo: &Topology, codec: &Codec, m_bytes: f64) -> HierStages {
    hier_stage_times_staged(topo, &StageCodecs::uniform(*codec), m_bytes)
}

/// [`hier_stage_times`] generalized to a codec per stage (the plan
/// compiler's pricing primitive): each stage's transfer volume is
/// compressed by *its* codec's wire ratio, and the QDQ pass accounting is
/// attributed per stage — stage 1 encodes/decode-sums with `intra_rs`,
/// the column ring encodes its M/s partial and decode-sums the G−1
/// remote images with `cross`, stage 3 encodes/decodes with `intra_ag`.
/// With a uniform `StageCodecs` this reproduces the calibrated uniform
/// accounting term for term.
pub fn hier_stage_times_staged(
    topo: &Topology,
    stages: &StageCodecs,
    m_bytes: f64,
) -> HierStages {
    let spec = &topo.spec;
    let groups = topo.numa_groups;
    let s = topo.group_size() as f64;
    let elems = m_bytes / 2.0;
    let ratio_rs = stages.intra_rs.compression_ratio(elems as usize);
    let ratio_x = stages.cross.compression_ratio(elems as usize);
    let ratio_ag = stages.intra_ag.compression_ratio(elems as usize);
    let cost_rs = codec_cost(&stages.intra_rs);
    let cost_x = codec_cost(&stages.cross);
    let cost_ag = codec_cost(&stages.intra_ag);
    // Intra-group RS: every rank sends (s-1)/s of its payload over the
    // fast fabric.
    let rs_intra = (s - 1.0) / s * m_bytes * ratio_rs / spec.intra_bw();
    // Cross-group leader ring: each adjacent link carries (G−1)·M (paper
    // accounting: M at G=2). An inadmissible (flat) topology prices to
    // +inf instead of panicking — Auto never asks, but nothing downstream
    // may crash on hostile shapes.
    let cross_vol = super::volume::cross_numa_volume(Algo::Hier, topo.n_gpus, groups, m_bytes);
    let cross = match topo.inter_bw() {
        Some(bw) => cross_vol * ratio_x / bw,
        None => f64::INFINITY,
    };
    // Intra-group AG mirrors the RS volume at its own codec's ratio.
    let ag_intra = (s - 1.0) / s * m_bytes * ratio_ag / spec.intra_bw();
    // QDQ, attributed per stage (uniform codecs sum to the calibrated
    // "encode M + M/s + M/s; decode(+reduce) (s-1)/s·M + (G−1)·M/s;
    // decode AG" accounting):
    let gm1 = (groups.max(2) - 1) as f64;
    let enc = elems * cost_rs.encode_passes
        + elems / s * cost_x.encode_passes
        + elems / s * cost_ag.encode_passes;
    let dec_red = elems * (s - 1.0) / s * (cost_rs.decode_passes + cost_rs.reduce_passes)
        + elems * gm1 / s * (cost_x.decode_passes + cost_x.reduce_passes);
    let dec = elems * (s - 1.0) / s * cost_ag.decode_passes;
    let qdq_total = pass_time(spec, 1.0, enc + dec_red + dec);
    HierStages { rs_intra, cross, ag_intra, qdq_total }
}

/// Build the micro-chunk pipeline DAG and schedule it (Fig. 8 bottom).
///
/// Resources: 0 = PCIe bus, 1 = NUMA bridge, 2 = comm SMs (QDQ). Each
/// chunk flows RS→X→AG with QDQ overlapped on the compute resource.
pub fn hier_pipeline_tasks(topo: &Topology, codec: &Codec, m_bytes: f64, chunks: usize) -> Vec<Task> {
    hier_pipeline_tasks_staged(topo, &StageCodecs::uniform(*codec), m_bytes, chunks)
}

/// [`hier_pipeline_tasks`] over per-stage codecs (plan pricing).
pub fn hier_pipeline_tasks_staged(
    topo: &Topology,
    stages: &StageCodecs,
    m_bytes: f64,
    chunks: usize,
) -> Vec<Task> {
    let st = hier_stage_times_staged(topo, stages, m_bytes);
    let k = chunks.max(1) as f64;
    let lat = topo.spec.stage_latency_s; // per-chunk kernel-launch overhead
    let qdq_share = st.qdq_total / (3.0 * k); // spread over stages & chunks
    let mut tasks = Vec::with_capacity(chunks * 5);
    for c in 0..chunks {
        let base = tasks.len();
        tasks.push(Task {
            label: format!("q{c}"),
            resource: 2,
            duration: qdq_share,
            deps: vec![],
        });
        tasks.push(Task {
            label: format!("R{c}"),
            resource: 0,
            duration: st.rs_intra / k + lat,
            deps: vec![base],
        });
        tasks.push(Task {
            label: format!("X{c}"),
            resource: 1,
            duration: st.cross / k + lat,
            deps: vec![base + 1],
        });
        tasks.push(Task {
            label: format!("A{c}"),
            resource: 0,
            duration: st.ag_intra / k + lat,
            deps: vec![base + 2],
        });
        tasks.push(Task {
            label: format!("d{c}"),
            resource: 2,
            duration: 2.0 * qdq_share,
            deps: vec![base + 3],
        });
    }
    tasks
}

fn hier_pipelined_time(topo: &Topology, codec: &Codec, m_bytes: f64, chunks: usize) -> TimeBreakdown {
    hier_pipelined_time_staged(topo, &StageCodecs::uniform(*codec), m_bytes, chunks)
}

fn hier_pipelined_time_staged(
    topo: &Topology,
    stages: &StageCodecs,
    m_bytes: f64,
    chunks: usize,
) -> TimeBreakdown {
    let tasks = hier_pipeline_tasks_staged(topo, stages, m_bytes, chunks);
    let sched = schedule(&tasks, 3);
    let st = hier_stage_times_staged(topo, stages, m_bytes);
    // Attribute the overlapped makespan: report transfer as the makespan
    // minus the (unoverlappable) QDQ remainder so the breakdown still sums.
    let lat = (2 + chunks) as f64 * topo.spec.stage_latency_s * 0.5;
    TimeBreakdown {
        transfer_s: sched.makespan - st.qdq_total / (chunks as f64),
        qdq_s: st.qdq_total / (chunks as f64),
        latency_s: lat,
    }
}

/// Serial (un-pipelined) makespan of the same chunked DAG — the Fig. 8
/// comparison bar.
pub fn hier_serial_makespan(topo: &Topology, codec: &Codec, m_bytes: f64, chunks: usize) -> f64 {
    serial_makespan(&hier_pipeline_tasks(topo, codec, m_bytes, chunks))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topo::{presets, Topology};

    fn c(s: &str) -> Codec {
        Codec::parse(s).unwrap()
    }

    const M: f64 = 64.0 * 1024.0 * 1024.0; // 64 MB per GPU

    #[test]
    fn l40_ring_bf16_matches_paper_anchor() {
        // Table 9: L40 NCCL BF16 = 10.43 GB/s. Calibration anchor: ±15%.
        let topo = Topology::new(presets::l40(), 8);
        let t = allreduce_time(&topo, Algo::Ring, &Codec::Bf16, M);
        let bw = algbw_gbps(M, &t);
        assert!((bw - 10.43).abs() / 10.43 < 0.15, "L40 ring bf16 {bw}");
    }

    #[test]
    fn l40_twostep_int8_loses_to_nccl_bf16() {
        // The paper's observed anomaly: two-step INT8 (9.17) < NCCL (10.43)
        // because two-step's cross-NUMA volume is ~2x the ring's.
        let topo = Topology::new(presets::l40(), 8);
        let ring = algbw_gbps(M, &allreduce_time(&topo, Algo::Ring, &Codec::Bf16, M));
        let two = algbw_gbps(M, &allreduce_time(&topo, Algo::TwoStep, &c("int8"), M));
        assert!(two < ring, "two-step INT8 {two} must lose to ring BF16 {ring}");
    }

    #[test]
    fn l40_low_bits_win_and_hier_beats_twostep() {
        let topo = Topology::new(presets::l40(), 8);
        for spec in ["int6", "int5", "int4@32", "int2-sr@32"] {
            let two = algbw_gbps(M, &allreduce_time(&topo, Algo::TwoStep, &c(spec), M));
            let hier = algbw_gbps(M, &allreduce_time(&topo, Algo::Hier, &c(spec), M));
            let ring = algbw_gbps(M, &allreduce_time(&topo, Algo::Ring, &Codec::Bf16, M));
            assert!(two > ring, "{spec}: two-step {two} vs ring {ring}");
            assert!(hier > two, "{spec}: hier {hier} vs two-step {two}");
        }
    }

    #[test]
    fn l40_pipelining_beats_serial_hier() {
        let topo = Topology::new(presets::l40(), 8);
        for spec in ["int8", "int5", "int2-sr@32"] {
            let hier = algbw_gbps(M, &allreduce_time(&topo, Algo::Hier, &c(spec), M));
            let pp = algbw_gbps(M, &allreduce_time(&topo, Algo::HierPipelined, &c(spec), M));
            assert!(pp > hier * 1.05, "{spec}: pp {pp} vs hier {hier}");
            assert!(pp < hier * 2.0, "{spec}: pp {pp} suspiciously high vs {hier}");
        }
    }

    #[test]
    fn hier_pp_max_speedup_over_nccl_near_3x(
    ) {
        // Paper: "maximum 3.2x speedup in AllReduce" (L40, hier+PP, low bits).
        let topo = Topology::new(presets::l40(), 8);
        let ring = algbw_gbps(M, &allreduce_time(&topo, Algo::Ring, &Codec::Bf16, M));
        let best = ["int4@32", "int3@32", "int2-sr@32"]
            .iter()
            .map(|s| algbw_gbps(M, &allreduce_time(&topo, Algo::HierPipelined, &c(s), M)))
            .fold(0.0, f64::max);
        let speedup = best / ring;
        assert!((2.4..=4.0).contains(&speedup), "speedup {speedup}");
    }

    #[test]
    fn nvlink_quantization_gains_ordered_by_cuda_capacity() {
        // Paper: up to 1.72x (A100), 1.99x (H800), 1.26x (H20).
        let mut gains = Vec::new();
        for spec in [presets::a100(), presets::h800(), presets::h20()] {
            let name = spec.name;
            let topo = Topology::new(spec, 8);
            let bf = algbw_gbps(M, &allreduce_time(&topo, Algo::Ring, &Codec::Bf16, M));
            let best = ["int8", "int6", "int5", "int4@32", "int3@32"]
                .iter()
                .map(|s| algbw_gbps(M, &allreduce_time(&topo, Algo::TwoStep, &c(s), M)))
                .fold(0.0, f64::max);
            gains.push((name, best / bf));
        }
        let (a100, h800, h20) = (gains[0].1, gains[1].1, gains[2].1);
        assert!(h800 > a100, "H800 {h800} must gain more than A100 {a100}");
        assert!(h20 < a100, "H20 {h20} must gain least");
        assert!(h20 > 1.0, "H20 still gains a little: {h20}");
    }

    #[test]
    fn int2_sr_not_best_on_nvlink() {
        // Paper: "INT2 is not the most beneficial in such a high-bandwidth
        // scenario" — QDQ+SR costs negate the volume win.
        for spec in [presets::a100(), presets::h20()] {
            let name = spec.name;
            let topo = Topology::new(spec, 8);
            let int4 = algbw_gbps(M, &allreduce_time(&topo, Algo::TwoStep, &c("int4@32"), M));
            let int2 =
                algbw_gbps(M, &allreduce_time(&topo, Algo::TwoStep, &c("int2-sr@32"), M));
            assert!(int2 < int4, "{name}: INT2_SR {int2} must lose to INT4 {int4}");
        }
    }

    #[test]
    fn quantized_ring_is_a_bad_idea() {
        // Ablation: a quantized ring QDQs at every hop — more QDQ time and
        // 2(N-1) launch latencies versus the two-step's 2 (and, in the real
        // fabric, N-1 compounding quantization errors; see comm tests).
        let topo = Topology::new(presets::a100(), 8);
        let ring_q = allreduce_time(&topo, Algo::Ring, &c("int8"), M);
        let two_q = allreduce_time(&topo, Algo::TwoStep, &c("int8"), M);
        assert!(ring_q.qdq_s > two_q.qdq_s * 1.2, "{} vs {}", ring_q.qdq_s, two_q.qdq_s);
        assert!(ring_q.latency_s > two_q.latency_s * 4.0);
    }

    #[test]
    fn generalized_group_pricing() {
        // 4-group PCIe box: the leader ring carries 3M per link vs 1M at
        // G=2, so the hier cross stage must cost ~3x more at equal bridge
        // speed — but the two-step still loses (its per-link 1.5M pays
        // against a fabric that hier's intra stages partly avoid too).
        let g2 = Topology::new(presets::l40(), 8);
        let g4 = presets::four_group_pcie(8).unwrap();
        let c4 = c("int4@32");
        let s2 = hier_stage_times(&g2, &c4, M);
        let s4 = hier_stage_times(&g4, &c4, M);
        assert!((s4.cross / s2.cross - 3.0).abs() < 1e-9, "{} vs {}", s4.cross, s2.cross);
        // Dual NVLink nodes: the slow inter-node link dominates the
        // two-step (4M across 25 GB/s) — hier's M across wins clearly.
        let duo = presets::dual_nvlink_node(16).unwrap();
        let two = allreduce_time(&duo, Algo::TwoStep, &c4, M).total();
        let hier = allreduce_time(&duo, Algo::Hier, &c4, M).total();
        assert!(hier < two / 2.0, "duo: hier {hier} must beat two-step {two} by >2x");
        // Flat topologies price the hierarchical family to +inf (never
        // selected, never a panic).
        let flat = Topology::new(presets::h800(), 8);
        assert!(hier_stage_times(&flat, &c4, M).cross.is_infinite());
    }

    #[test]
    fn staged_pricing_uniform_matches_legacy_accounting() {
        // The staged decomposition must reproduce the pre-plan calibrated
        // uniform formulas (same terms, regrouped — agreement to
        // rounding). The legacy closed form is kept inline here as the
        // golden reference.
        for topo in [
            Topology::new(presets::l40(), 8),
            presets::four_group_pcie(8).unwrap(),
            presets::dual_nvlink_node(16).unwrap(),
        ] {
            for spec in ["bf16", "int8", "int4@32", "int2-sr@32!"] {
                let codec = c(spec);
                let st = hier_stage_times(&topo, &codec, M);

                let sp = &topo.spec;
                let s = topo.group_size() as f64;
                let elems = M / 2.0;
                let ratio = codec.compression_ratio(elems as usize);
                let cost = crate::sim::cost::codec_cost(&codec);
                let rs = (s - 1.0) / s * M * ratio / sp.intra_bw();
                let cross_vol = crate::sim::volume::cross_numa_volume(
                    Algo::Hier,
                    topo.n_gpus,
                    topo.numa_groups,
                    M,
                );
                let cross = cross_vol * ratio / topo.inter_bw().unwrap();
                let enc = elems * (1.0 + 2.0 / s) * cost.encode_passes;
                let gm1 = (topo.numa_groups.max(2) - 1) as f64;
                let dec_red = elems * ((s - 1.0) / s + gm1 / s)
                    * (cost.decode_passes + cost.reduce_passes);
                let dec = elems * (s - 1.0) / s * cost.decode_passes;
                let qdq = crate::sim::cost::pass_time(sp, 1.0, enc + dec_red + dec);

                assert_eq!(st.rs_intra, rs, "{spec}");
                assert_eq!(st.cross, cross, "{spec}");
                assert_eq!(st.ag_intra, rs, "{spec}");
                let rel = (st.qdq_total - qdq).abs() / qdq;
                assert!(rel < 1e-12, "{spec}: qdq {} vs legacy {qdq}", st.qdq_total);
            }
        }
    }

    #[test]
    fn aggressive_cross_codec_cuts_the_slow_link_time() {
        // On the dual-NVLink cluster the 25 GB/s inter-node ring dominates;
        // an int2-sr cross stage under an int4 budget must shrink `cross`
        // in proportion to the wire ratios while leaving the intra stages
        // untouched — and win end-to-end despite its extra QDQ passes.
        let duo = presets::dual_nvlink_node(8).unwrap();
        let base = c("int4@32");
        let uni = crate::plan::StageCodecs::uniform(base);
        let mixed = crate::plan::StageCodecs::with_cross(base, c("int2-sr@32!"));
        let tu = hier_stage_times_staged(&duo, &uni, M);
        let tm = hier_stage_times_staged(&duo, &mixed, M);
        assert_eq!(tu.rs_intra, tm.rs_intra);
        assert_eq!(tu.ag_intra, tm.ag_intra);
        assert!(tm.cross < tu.cross, "{} vs {}", tm.cross, tu.cross);
        assert!(tm.qdq_total > tu.qdq_total, "SR costs more QDQ passes");
        let plan_u = crate::plan::CommPlan {
            algo: Algo::Hier,
            stage_codecs: uni,
            chunks: 1,
            send_window: 1,
            codec_threads: 0,
        };
        let plan_m = crate::plan::CommPlan { stage_codecs: mixed, ..plan_u };
        assert!(
            plan_time(&duo, &plan_m, M).total() < plan_time(&duo, &plan_u, M).total(),
            "mixed must price faster on the asymmetric cluster"
        );
    }

    #[test]
    fn plan_time_matches_allreduce_time_for_uniform_defaults() {
        let l40 = Topology::new(presets::l40(), 8);
        let duo = presets::dual_nvlink_node(8).unwrap();
        for topo in [&l40, &duo] {
            for (algo, spec) in [
                (Algo::Ring, "bf16"),
                (Algo::TwoStep, "int8"),
                (Algo::Hier, "int4@32"),
            ] {
                let codec = c(spec);
                let plan = crate::plan::CommPlan::uniform(algo, codec);
                let a = plan_time(topo, &plan, M).total();
                let b = allreduce_time(topo, algo, &codec, M).total();
                assert!((a - b).abs() <= b * 1e-12, "{algo:?} {spec}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn breakdown_components_positive_and_sum() {
        let topo = Topology::new(presets::l40(), 8);
        let t = allreduce_time(&topo, Algo::Hier, &c("int5"), M);
        assert!(t.transfer_s > 0.0 && t.qdq_s > 0.0 && t.latency_s > 0.0);
        assert!((t.total() - (t.transfer_s + t.qdq_s + t.latency_s)).abs() < 1e-12);
    }
}
