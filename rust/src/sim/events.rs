//! Minimal event-driven resource scheduler.
//!
//! The pipelined hierarchical AllReduce (Fig. 8) is a classic
//! resource-constrained DAG: micro-chunk stages contend for two shared
//! resources (the intra-NUMA PCIe bus and the NUMA bridge). This module
//! computes the makespan of such a DAG: each task has a duration, a
//! resource it occupies exclusively, and dependency edges; tasks on the
//! same resource run serially in their release order, tasks on different
//! resources overlap freely.
//!
//! The same scheduler produces the Fig. 8 timeline dump (`flashcomm
//! figure 8`).

/// A schedulable task.
#[derive(Debug, Clone)]
pub struct Task {
    /// Display label (used in the timeline rendering).
    pub label: String,
    /// Resource index the task occupies exclusively.
    pub resource: usize,
    /// Execution time in seconds.
    pub duration: f64,
    /// Indices of tasks that must complete first.
    pub deps: Vec<usize>,
}

/// One scheduled task instance.
#[derive(Debug, Clone, PartialEq)]
pub struct Scheduled {
    pub start: f64,
    pub end: f64,
}

/// Result of scheduling a DAG.
#[derive(Debug, Clone)]
pub struct Schedule {
    pub tasks: Vec<Scheduled>,
    pub makespan: f64,
    /// Idle time per resource inside the busy window (the Fig. 8 bubbles).
    pub bubbles: Vec<f64>,
}

/// List-schedule the DAG: repeatedly pick, among tasks whose dependencies
/// have completed, the one that can start earliest (ties broken by index),
/// and run it on its resource. Tasks must be topologically ordered (deps
/// point backwards), which the builders in `sim::allreduce` guarantee.
pub fn schedule(tasks: &[Task], n_resources: usize) -> Schedule {
    let n = tasks.len();
    let mut done = vec![Scheduled { start: 0.0, end: 0.0 }; n];
    let mut scheduled = vec![false; n];
    let mut resource_free = vec![0.0f64; n_resources];
    let mut resource_busy = vec![0.0f64; n_resources];
    for (i, t) in tasks.iter().enumerate() {
        assert!(t.resource < n_resources, "task {i} resource out of range");
        for &d in &t.deps {
            assert!(d < i, "deps must point backwards (task {i} dep {d})");
        }
    }
    for _ in 0..n {
        // Earliest-start ready task.
        let mut best: Option<(f64, usize)> = None;
        for (i, t) in tasks.iter().enumerate() {
            if scheduled[i] || !t.deps.iter().all(|&d| scheduled[d]) {
                continue;
            }
            let ready =
                t.deps.iter().map(|&d| done[d].end).fold(0.0f64, f64::max);
            let start = ready.max(resource_free[t.resource]);
            if best.map_or(true, |(s, _)| start < s) {
                best = Some((start, i));
            }
        }
        let (start, i) = best.expect("cycle or unreachable task in DAG");
        let t = &tasks[i];
        let end = start + t.duration;
        scheduled[i] = true;
        resource_free[t.resource] = end;
        resource_busy[t.resource] += t.duration;
        done[i] = Scheduled { start, end };
    }
    let makespan = done.iter().map(|s| s.end).fold(0.0, f64::max);
    let bubbles = (0..n_resources)
        .map(|r| {
            let window = done
                .iter()
                .zip(tasks)
                .filter(|(_, t)| t.resource == r)
                .map(|(s, _)| s.end)
                .fold(0.0, f64::max);
            (window - resource_busy[r]).max(0.0)
        })
        .collect();
    Schedule { tasks: done, makespan, bubbles }
}

/// Serial makespan (no overlap at all): the sum of all durations. This is
/// the "Serial Execution" upper bar of Fig. 8.
pub fn serial_makespan(tasks: &[Task]) -> f64 {
    tasks.iter().map(|t| t.duration).sum()
}

/// Render an ASCII Gantt chart of a schedule (Fig. 8 visualization).
pub fn render_timeline(
    tasks: &[Task],
    sched: &Schedule,
    resource_names: &[&str],
    width: usize,
) -> String {
    let span = sched.makespan.max(1e-12);
    let mut out = String::new();
    for (r, name) in resource_names.iter().enumerate() {
        let mut row = vec![' '; width];
        for (t, s) in tasks.iter().zip(&sched.tasks) {
            if t.resource != r {
                continue;
            }
            let a = ((s.start / span) * width as f64) as usize;
            let b = (((s.end / span) * width as f64).ceil() as usize).min(width);
            let c = t.label.chars().next().unwrap_or('#');
            for cell in row.iter_mut().take(b).skip(a) {
                *cell = c;
            }
        }
        out.push_str(&format!("{name:>10} |{}|\n", row.iter().collect::<String>()));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(label: &str, resource: usize, duration: f64, deps: &[usize]) -> Task {
        Task { label: label.into(), resource, duration, deps: deps.to_vec() }
    }

    #[test]
    fn independent_tasks_on_different_resources_overlap() {
        let tasks = vec![t("a", 0, 1.0, &[]), t("b", 1, 1.0, &[])];
        let s = schedule(&tasks, 2);
        assert_eq!(s.makespan, 1.0);
        assert_eq!(serial_makespan(&tasks), 2.0);
    }

    #[test]
    fn same_resource_serializes() {
        let tasks = vec![t("a", 0, 1.0, &[]), t("b", 0, 2.0, &[])];
        let s = schedule(&tasks, 1);
        assert_eq!(s.makespan, 3.0);
        assert_eq!(s.tasks[1].start, 1.0);
    }

    #[test]
    fn deps_enforce_order_across_resources() {
        let tasks = vec![t("a", 0, 1.0, &[]), t("b", 1, 1.0, &[0])];
        let s = schedule(&tasks, 2);
        assert_eq!(s.tasks[1].start, 1.0);
        assert_eq!(s.makespan, 2.0);
    }

    #[test]
    fn two_stage_pipeline_hides_all_but_one_chunk() {
        // K chunks through stages A(res0) -> B(res1), equal durations d:
        // makespan = (K+1) d, vs serial 2 K d.
        let k = 8;
        let d = 0.5;
        let mut tasks = Vec::new();
        for c in 0..k {
            let a = tasks.len();
            tasks.push(t(&format!("A{c}"), 0, d, &[]));
            tasks.push(t(&format!("B{c}"), 1, d, &[a]));
        }
        let s = schedule(&tasks, 2);
        assert!((s.makespan - (k as f64 + 1.0) * d).abs() < 1e-9, "{}", s.makespan);
        assert!((serial_makespan(&tasks) - 2.0 * k as f64 * d).abs() < 1e-9);
    }

    #[test]
    fn bubbles_accounting() {
        // Resource 1 waits 1s for the dep: bubble of 1s before its window.
        let tasks = vec![t("a", 0, 1.0, &[]), t("b", 1, 1.0, &[0])];
        let s = schedule(&tasks, 2);
        assert!((s.bubbles[1] - 1.0).abs() < 1e-9);
        assert_eq!(s.bubbles[0], 0.0);
    }

    #[test]
    fn timeline_renders_rows() {
        let tasks = vec![t("R", 0, 1.0, &[]), t("X", 1, 1.0, &[0])];
        let s = schedule(&tasks, 2);
        let viz = render_timeline(&tasks, &s, &["pcie", "bridge"], 40);
        assert_eq!(viz.lines().count(), 2);
        assert!(viz.contains('R') && viz.contains('X'));
    }
}
