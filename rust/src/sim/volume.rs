//! Closed-form communication-volume accounting (Table 5, generalized).
//!
//! Volumes follow the paper's own accounting for an N-GPU system in G
//! link-tier groups, M bytes of payload per GPU. The paper's Table 5 is
//! the `N = 8, G = 2` column:
//!
//! | Method                | total  | cross-group (busiest link) |
//! |-----------------------|--------|----------------------------|
//! | NCCL (ring)           | 14 M   | 7M/4                       |
//! | Two-step              | 14 M   | 4 M                        |
//! | Hierarchical two-step | 14 M   | M                          |
//!
//! The cross-group column generalizes per *inter-group link*, under a
//! ring-of-groups physical model: `G = 2` has a single bridge; `G > 2` has
//! one link per adjacent group pair (G links) with all-to-all traffic
//! assumed balanced across them. The hierarchical entry is exact (the
//! leader column ring really does put (G−1)·M/s per rank on its adjacent
//! links); the ring/two-step entries are the busiest-link load the cost
//! model charges.

/// The algorithm enum lives with the collectives ([`crate::comm::Algo`]);
/// this re-export keeps the timing model's historical `sim::volume::Algo`
/// path working.
pub use crate::comm::Algo;

/// Total bytes moved across all links for an AllReduce of `m` bytes/GPU.
pub fn total_volume(algo: Algo, n: usize, m: f64) -> f64 {
    let nf = n as f64;
    match algo {
        // Ring: 2(N-1) steps of M/N per GPU, N GPUs => 2(N-1)M.
        Algo::Ring => 2.0 * (nf - 1.0) * m,
        // One-shot RS: each GPU sends (N-1)/N·M; AG the same => 2(N-1)M.
        Algo::TwoStep => 2.0 * (nf - 1.0) * m,
        // Intra RS (s-1)/s·M·N + cross + intra AG — same total 2(N-1)M
        // under the paper's accounting.
        Algo::Hier | Algo::HierPipelined => 2.0 * (nf - 1.0) * m,
    }
}

/// Physical inter-group links of a G-group machine: one shared bridge at
/// `G = 2`, a ring of one-per-adjacent-pair at `G > 2` (0 for flat
/// machines). The one place this model lives — the all2all cost model
/// ([`super::all2all`]) shares it.
pub fn inter_group_links(groups: usize) -> f64 {
    match groups {
        0 | 1 => 0.0,
        2 => 1.0,
        g => g as f64,
    }
}

/// Bytes crossing the busiest inter-group link (one direction — the
/// paper's Volume_CrossNUMA column at `groups = 2`), for `groups >= 1`
/// equal groups. Flat topologies (`groups <= 1`) cross nothing.
pub fn cross_numa_volume(algo: Algo, n: usize, groups: usize, m: f64) -> f64 {
    if groups <= 1 {
        return 0.0;
    }
    let nf = n as f64;
    let g = groups as f64;
    let links = inter_group_links(groups);
    match algo {
        // The rank ring crosses each group boundary once with
        // 2(N-1)/N·M worth of traffic — per boundary edge, independent of
        // G (the paper counts 7M/4 at N=8).
        Algo::Ring => 2.0 * (nf - 1.0) / nf * m,
        // Every (rank, peer) pair in different groups exchanges M/N in RS
        // and again in AG: aggregate N·(1−1/G)·M per direction, balanced
        // across the links (= 4M at N=8, G=2).
        Algo::TwoStep => nf * (1.0 - 1.0 / g) * m / links,
        // Each of the s leader columns rings (G−1) chunk wires of M/s past
        // every adjacent link: s · (G−1) · M/s = (G−1)·M per link
        // (= M at G=2 — only the s bridge pairs move their partial chunk).
        Algo::Hier | Algo::HierPipelined => (g - 1.0) * m,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table5_exact() {
        let m = 1.0;
        let n = 8;
        for algo in [Algo::Ring, Algo::TwoStep, Algo::Hier] {
            assert_eq!(total_volume(algo, n, m), 14.0, "{}", algo.name());
        }
        assert!((cross_numa_volume(Algo::Ring, n, 2, m) - 7.0 / 4.0).abs() < 1e-12);
        assert_eq!(cross_numa_volume(Algo::TwoStep, n, 2, m), 4.0);
        assert_eq!(cross_numa_volume(Algo::Hier, n, 2, m), 1.0);
    }

    #[test]
    fn hier_saves_3x_cross_numa() {
        // "saving 3 times cross-NUMA communication volume" vs two-step.
        let two = cross_numa_volume(Algo::TwoStep, 8, 2, 1.0);
        let hier = cross_numa_volume(Algo::Hier, 8, 2, 1.0);
        assert_eq!(two - hier, 3.0);
    }

    #[test]
    fn generalized_groups() {
        // G = 1: nothing crosses.
        for algo in [Algo::Ring, Algo::TwoStep, Algo::Hier] {
            assert_eq!(cross_numa_volume(algo, 8, 1, 1.0), 0.0);
        }
        // G = 4, N = 8: two-step aggregate 8·(3/4) = 6M over 4 links;
        // hier column ring (G−1)M = 3M per link.
        assert_eq!(cross_numa_volume(Algo::TwoStep, 8, 4, 1.0), 1.5);
        assert_eq!(cross_numa_volume(Algo::Hier, 8, 4, 1.0), 3.0);
        // Hier's per-link load still beats the ring's boundary load and
        // stays below two-step's aggregate (6M) at G=4.
        assert!(cross_numa_volume(Algo::Hier, 8, 4, 1.0) > cross_numa_volume(Algo::Ring, 8, 4, 1.0));
    }

    #[test]
    fn volumes_scale_linearly_in_m() {
        for algo in [Algo::Ring, Algo::TwoStep, Algo::Hier] {
            for g in [2usize, 4] {
                assert_eq!(
                    cross_numa_volume(algo, 8, g, 2.0),
                    2.0 * cross_numa_volume(algo, 8, g, 1.0)
                );
            }
        }
    }
}
