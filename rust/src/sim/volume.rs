//! Closed-form communication-volume accounting (Table 5).
//!
//! Volumes follow the paper's own accounting for an N-GPU node with two
//! NUMA groups, M bytes of payload per GPU:
//!
//! | Method                | total  | cross-NUMA |
//! |-----------------------|--------|------------|
//! | NCCL (ring)           | 14 M   | 7M/4       |
//! | Two-step              | 14 M   | 4 M        |
//! | Hierarchical two-step | 14 M   | M          |
//!
//! (Table 5 numbers are for N = 8; the formulas below generalize.)

/// The algorithm enum lives with the collectives ([`crate::comm::Algo`]);
/// this re-export keeps the timing model's historical `sim::volume::Algo`
/// path working.
pub use crate::comm::Algo;

/// Total bytes moved across all links for an AllReduce of `m` bytes/GPU.
pub fn total_volume(algo: Algo, n: usize, m: f64) -> f64 {
    let nf = n as f64;
    match algo {
        // Ring: 2(N-1) steps of M/N per GPU, N GPUs => 2(N-1)M.
        Algo::Ring => 2.0 * (nf - 1.0) * m,
        // One-shot RS: each GPU sends (N-1)/N·M; AG the same => 2(N-1)M.
        Algo::TwoStep => 2.0 * (nf - 1.0) * m,
        // Intra RS (s-1)/s·M·N + cross M + intra AG — same total 2(N-1)M
        // under the paper's accounting.
        Algo::Hier | Algo::HierPipelined => 2.0 * (nf - 1.0) * m,
    }
}

/// Bytes crossing the NUMA bridge (the paper's Volume_CrossNUMA column),
/// for `groups` NUMA groups (Table 5 uses 2 groups of N/2).
pub fn cross_numa_volume(algo: Algo, n: usize, groups: usize, m: f64) -> f64 {
    assert!(groups == 2, "the paper's node has two NUMA groups");
    let nf = n as f64;
    let s = nf / groups as f64; // ranks per group
    match algo {
        // The ring crosses the boundary on 2(N-1)/N·M worth of traffic for
        // one boundary edge pair — the paper counts 7M/4 at N=8.
        Algo::Ring => 2.0 * (nf - 1.0) / nf * m,
        // Every (rank, peer) pair in different groups exchanges M/N in RS
        // and again in AG: 2 · s · s · 2 · M/N = N·M/2 (= 4M at N=8).
        Algo::TwoStep => nf * m / 2.0,
        // Only the s bridge pairs move their M/s partial chunk (= M).
        Algo::Hier | Algo::HierPipelined => s * (m / s),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table5_exact() {
        let m = 1.0;
        let n = 8;
        for algo in [Algo::Ring, Algo::TwoStep, Algo::Hier] {
            assert_eq!(total_volume(algo, n, m), 14.0, "{}", algo.name());
        }
        assert!((cross_numa_volume(Algo::Ring, n, 2, m) - 7.0 / 4.0).abs() < 1e-12);
        assert_eq!(cross_numa_volume(Algo::TwoStep, n, 2, m), 4.0);
        assert_eq!(cross_numa_volume(Algo::Hier, n, 2, m), 1.0);
    }

    #[test]
    fn hier_saves_3x_cross_numa() {
        // "saving 3 times cross-NUMA communication volume" vs two-step.
        let two = cross_numa_volume(Algo::TwoStep, 8, 2, 1.0);
        let hier = cross_numa_volume(Algo::Hier, 8, 2, 1.0);
        assert_eq!(two - hier, 3.0);
    }

    #[test]
    fn volumes_scale_linearly_in_m() {
        for algo in [Algo::Ring, Algo::TwoStep, Algo::Hier] {
            assert_eq!(
                cross_numa_volume(algo, 8, 2, 2.0),
                2.0 * cross_numa_volume(algo, 8, 2, 1.0)
            );
        }
    }
}
