//! Link-level performance simulator.
//!
//! The paper's bandwidth tables are *algorithmic bandwidth* measurements —
//! payload ÷ wall time — on hardware we do not have. This module predicts
//! them from first principles: per-stage link volumes (× codec wire ratio)
//! over calibrated effective bandwidths, plus a QDQ compute tax, with an
//! event-driven scheduler for the pipelined hierarchical variant. See
//! DESIGN.md §2 for why this substitution preserves the paper's shape.

pub mod all2all;
pub mod allreduce;
pub mod cost;
pub mod events;
pub mod profile;
pub mod volume;

pub use allreduce::{algbw_gbps, allreduce_time, plan_time, TimeBreakdown};
pub use profile::MeasuredProfile;
/// Re-export of [`crate::comm::Algo`] — the enum's home is the collective
/// layer; the simulator prices its algorithms.
pub use volume::Algo;
