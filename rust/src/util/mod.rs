//! Shared substrate: bf16 codec, deterministic PRNG, statistics, timing and
//! a minimal property-testing harness. Everything here is dependency-free
//! (the offline vendor set only carries the `xla` closure).

pub mod backoff;
pub mod bf16;
pub mod prng;
pub mod proptest;
pub mod stats;
pub mod timer;

pub use backoff::Backoff;
pub use bf16::Bf16;
pub use prng::Prng;
