//! Capped jittered-exponential backoff, deterministic under a seed.
//!
//! One retry policy shared by every layer that polls a peer that may not
//! be ready yet: TCP's rendezvous/mesh `connect_retry`, and the UDP
//! transport's NACK and probe-retransmit timers. The schedule is classic
//! equal-jitter exponential backoff: attempt `k` waits
//!
//! ```text
//! delay(k) = min(cap, base * 2^k) * (0.5 + 0.5 * u)      u ~ U[0, 1)
//! ```
//!
//! so consecutive retries from many ranks decorrelate (no thundering herd
//! against the rendezvous root, no synchronized NACK storms after a burst
//! loss) while the expected wait still doubles until it hits `cap`. The
//! jitter stream comes from [`Prng`], so a seeded `Backoff` replays the
//! exact same delay sequence — tests and the wire-fault harness stay
//! deterministic.

use std::time::Duration;

use super::Prng;

/// A jittered-exponential retry schedule. Construct once per retried
/// operation; call [`next_delay`](Backoff::next_delay) before each retry
/// and [`reset`](Backoff::reset) after a success.
#[derive(Debug)]
pub struct Backoff {
    base: Duration,
    cap: Duration,
    attempt: u32,
    rng: Prng,
}

impl Backoff {
    /// `base` is the un-jittered first delay, `cap` bounds the exponential
    /// growth, `seed` fixes the jitter stream.
    pub fn new(base: Duration, cap: Duration, seed: u64) -> Self {
        assert!(base > Duration::ZERO, "backoff base must be positive");
        assert!(cap >= base, "backoff cap must be >= base");
        Self { base, cap, attempt: 0, rng: Prng::new(seed) }
    }

    /// The delay to sleep before the next retry. Advances the attempt
    /// counter: successive calls grow `base, 2*base, 4*base, ...` (each
    /// equal-jittered into `[d/2, d)`) until the un-jittered value hits
    /// `cap`.
    pub fn next_delay(&mut self) -> Duration {
        // Saturating shift: past attempt 63 the doubling has long been
        // clamped by `cap` anyway.
        let factor = 1u64.checked_shl(self.attempt.min(63)).unwrap_or(u64::MAX);
        let raw = self.base.saturating_mul(factor.min(u32::MAX as u64) as u32).min(self.cap);
        self.attempt = self.attempt.saturating_add(1);
        let jitter = 0.5 + 0.5 * self.rng.next_f64();
        Duration::from_secs_f64(raw.as_secs_f64() * jitter)
    }

    /// How many delays have been handed out since construction/reset.
    pub fn attempts(&self) -> u32 {
        self.attempt
    }

    /// Start the schedule over (after a success). The jitter stream keeps
    /// advancing — only the exponential clock rewinds.
    pub fn reset(&mut self) {
        self.attempt = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ms(v: u64) -> Duration {
        Duration::from_millis(v)
    }

    #[test]
    fn delays_grow_exponentially_within_jitter_bounds() {
        let mut b = Backoff::new(ms(10), ms(10_000), 42);
        for k in 0..6u32 {
            let expect = ms(10 * (1 << k));
            let d = b.next_delay();
            assert!(d >= expect / 2, "attempt {k}: {d:?} below half of {expect:?}");
            assert!(d < expect, "attempt {k}: {d:?} not below un-jittered {expect:?}");
        }
        assert_eq!(b.attempts(), 6);
    }

    #[test]
    fn cap_bounds_growth() {
        let mut b = Backoff::new(ms(10), ms(50), 7);
        for _ in 0..20 {
            assert!(b.next_delay() < ms(50), "jittered delay must stay under cap");
        }
        // Deep into the schedule the un-jittered delay is pinned at cap,
        // so the jittered value stays in [cap/2, cap).
        let d = b.next_delay();
        assert!(d >= ms(25));
    }

    #[test]
    fn seeded_schedules_replay_exactly() {
        let mut a = Backoff::new(ms(5), ms(1000), 99);
        let mut b = Backoff::new(ms(5), ms(1000), 99);
        for _ in 0..10 {
            assert_eq!(a.next_delay(), b.next_delay());
        }
        let mut c = Backoff::new(ms(5), ms(1000), 100);
        let differs = (0..10).any(|_| a.next_delay() != c.next_delay());
        assert!(differs, "different seeds should jitter differently");
    }

    #[test]
    fn reset_rewinds_the_exponential_clock() {
        let mut b = Backoff::new(ms(10), ms(10_000), 3);
        for _ in 0..5 {
            b.next_delay();
        }
        b.reset();
        assert_eq!(b.attempts(), 0);
        let d = b.next_delay();
        assert!(d >= ms(5) && d < ms(10), "post-reset delay is back at base: {d:?}");
    }

    #[test]
    fn huge_attempt_counts_do_not_overflow() {
        let mut b = Backoff::new(ms(1), Duration::from_secs(2), 1);
        for _ in 0..200 {
            let d = b.next_delay();
            assert!(d <= Duration::from_secs(2));
        }
    }
}
