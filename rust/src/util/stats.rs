//! Error / distribution statistics used by the accuracy experiments
//! (Tables 1–3, Fig. 4) and by tests asserting quantization quality.

/// Mean squared error between two equal-length slices.
pub fn mse(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    if a.is_empty() {
        return 0.0;
    }
    let mut acc = 0.0f64;
    for (x, y) in a.iter().zip(b) {
        let d = (*x - *y) as f64;
        acc += d * d;
    }
    acc / a.len() as f64
}

/// Maximum absolute error.
pub fn max_abs_err(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| ((*x - *y) as f64).abs()).fold(0.0, f64::max)
}

/// Signal-to-quantization-noise ratio in dB: 10 log10(E[x^2] / E[(x-x̂)^2]).
/// Returns +inf for a perfect reconstruction.
pub fn sqnr_db(original: &[f32], reconstructed: &[f32]) -> f64 {
    let signal: f64 =
        original.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>() / original.len() as f64;
    let noise = mse(original, reconstructed);
    if noise == 0.0 {
        return f64::INFINITY;
    }
    10.0 * (signal / noise).log10()
}

/// Summary of a distribution (Fig. 4-style before/after comparison).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DistSummary {
    pub n: usize,
    pub min: f64,
    pub max: f64,
    pub mean: f64,
    pub std: f64,
    /// Excess kurtosis — large for spiky/heavy-tailed data.
    pub kurtosis: f64,
}

impl DistSummary {
    pub fn of(xs: &[f32]) -> Self {
        let n = xs.len();
        assert!(n > 0, "empty distribution");
        let mut min = f64::INFINITY;
        let mut max = f64::NEG_INFINITY;
        let mut mean = 0.0;
        for &x in xs {
            let x = x as f64;
            min = min.min(x);
            max = max.max(x);
            mean += x;
        }
        mean /= n as f64;
        let (mut m2, mut m4) = (0.0, 0.0);
        for &x in xs {
            let d = x as f64 - mean;
            let d2 = d * d;
            m2 += d2;
            m4 += d2 * d2;
        }
        m2 /= n as f64;
        m4 /= n as f64;
        let kurtosis = if m2 > 0.0 { m4 / (m2 * m2) - 3.0 } else { 0.0 };
        DistSummary { n, min, max, mean, std: m2.sqrt(), kurtosis }
    }

    /// Dynamic range (max - min) — the quantity spike reserving shrinks.
    pub fn range(&self) -> f64 {
        self.max - self.min
    }
}

/// Fixed-width ASCII histogram used by `flashcomm figure 4`.
pub fn ascii_histogram(xs: &[f32], bins: usize, width: usize) -> String {
    assert!(bins >= 2);
    let s = DistSummary::of(xs);
    let lo = s.min;
    let hi = if s.max > s.min { s.max } else { s.min + 1.0 };
    let mut counts = vec![0usize; bins];
    for &x in xs {
        let t = ((x as f64 - lo) / (hi - lo) * bins as f64) as usize;
        counts[t.min(bins - 1)] += 1;
    }
    let peak = *counts.iter().max().unwrap() as f64;
    let mut out = String::new();
    for (i, &c) in counts.iter().enumerate() {
        let left = lo + (hi - lo) * i as f64 / bins as f64;
        // Log-scaled bar so rare outlier bins stay visible.
        let bar = if c == 0 {
            0
        } else {
            (((c as f64).ln() + 1.0) / (peak.ln() + 1.0) * width as f64).ceil() as usize
        };
        out.push_str(&format!("{left:>10.3} | {:<width$} {c}\n", "#".repeat(bar.min(width))));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    #[test]
    fn mse_zero_for_identical() {
        let a = vec![1.0f32, -2.0, 3.0];
        assert_eq!(mse(&a, &a), 0.0);
        assert_eq!(sqnr_db(&a, &a), f64::INFINITY);
    }

    #[test]
    fn mse_known_value() {
        let a = vec![0.0f32, 0.0];
        let b = vec![1.0f32, -1.0];
        assert_eq!(mse(&a, &b), 1.0);
        assert_eq!(max_abs_err(&a, &b), 1.0);
    }

    #[test]
    fn sqnr_orders_precision() {
        // A finer perturbation must yield a higher SQNR.
        let mut rng = Prng::new(9);
        let x: Vec<f32> = (0..4096).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let coarse: Vec<f32> = x.iter().map(|v| v + 0.1).collect();
        let fine: Vec<f32> = x.iter().map(|v| v + 0.01).collect();
        assert!(sqnr_db(&x, &fine) > sqnr_db(&x, &coarse) + 15.0);
    }

    #[test]
    fn summary_of_uniform() {
        let xs: Vec<f32> = (0..10_001).map(|i| i as f32 / 10_000.0).collect();
        let s = DistSummary::of(&xs);
        assert!((s.mean - 0.5).abs() < 1e-3);
        assert!((s.min - 0.0).abs() < 1e-6 && (s.max - 1.0).abs() < 1e-6);
        // Uniform excess kurtosis is -1.2.
        assert!((s.kurtosis + 1.2).abs() < 0.05, "kurtosis {}", s.kurtosis);
    }

    #[test]
    fn heavy_tails_have_positive_kurtosis() {
        let mut rng = Prng::new(10);
        let mut xs = vec![0f32; 1 << 15];
        rng.fill_activations(&mut xs, 1.0);
        let s = DistSummary::of(&xs);
        assert!(s.kurtosis > 2.0, "kurtosis {}", s.kurtosis);
    }

    #[test]
    fn histogram_renders() {
        let xs = vec![0.0f32, 0.1, 0.2, 0.9, 1.0];
        let h = ascii_histogram(&xs, 4, 20);
        assert_eq!(h.lines().count(), 4);
    }
}
