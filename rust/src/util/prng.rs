//! Deterministic PRNG for tests, property sweeps and synthetic workloads.
//!
//! xoshiro256** core with Box–Muller normals and a Student-t sampler used to
//! synthesize heavy-tailed "activation-like" tensors (the distributions the
//! paper's spike reserving targets — Fig. 4). No external `rand` crate is
//! available offline, so this is self-contained and reproducible by seed.

/// Deterministic xoshiro256** generator.
#[derive(Clone, Debug)]
pub struct Prng {
    s: [u64; 4],
    /// Cached second normal from Box–Muller.
    spare_normal: Option<f64>,
}

impl Prng {
    /// Seed via SplitMix64 so any u64 (including 0) gives a good state.
    pub fn new(seed: u64) -> Self {
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Prng { s: [next(), next(), next(), next()], spare_normal: None }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_f64() * n as f64) as usize % n
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f32()
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u1 = self.next_f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.next_f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.spare_normal = Some(r * s);
            return r * c;
        }
    }

    /// Normal with given mean / std.
    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Student-t with `dof` degrees of freedom — heavy-tailed, the shape of
    /// post-GELU transformer activations the paper quantizes (spiky tails).
    pub fn student_t(&mut self, dof: f64) -> f64 {
        // t = N / sqrt(ChiSq(k)/k); ChiSq(k) as sum of k squared normals is
        // fine for the small dof we use (2..8).
        let n = self.normal();
        let k = dof.max(1.0) as usize;
        let mut chi = 0.0;
        for _ in 0..k {
            let z = self.normal();
            chi += z * z;
        }
        n / (chi / dof).sqrt()
    }

    /// Fill a buffer with standard normals.
    pub fn fill_normal(&mut self, out: &mut [f32], mean: f32, std: f32) {
        for v in out.iter_mut() {
            *v = self.normal_f32(mean, std);
        }
    }

    /// Fill with an "activation-like" heavy-tailed distribution: Student-t
    /// body plus rare massive outliers (Sun et al. 2024a, "massive
    /// activations"), matching the paper's Fig. 4 profile.
    pub fn fill_activations(&mut self, out: &mut [f32], scale: f32) {
        for v in out.iter_mut() {
            let body = self.student_t(4.0) as f32 * scale;
            // ~0.1% massive outliers at 20-60x the body scale.
            if self.next_f64() < 1e-3 {
                let sign = if self.next_u64() & 1 == 0 { 1.0 } else { -1.0 };
                *v = sign * scale * (20.0 + 40.0 * self.next_f32());
            } else {
                *v = body;
            }
        }
    }

    /// Zipf-distributed integer in [0, n) with exponent `s` (corpus synthesis).
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        // Inverse-CDF on a precomputed-free approximation: rejection-free
        // bounded harmonic walk is overkill; n here is small (vocab-sized),
        // so a direct CDF walk with cached normalizer would be O(n). Use the
        // standard approximation via inverse transform of the continuous
        // bounded Pareto, clamped to the support.
        let u = self.next_f64().max(1e-12);
        if (s - 1.0).abs() < 1e-9 {
            let h = (n as f64).ln();
            return ((u * h).exp() - 1.0).min((n - 1) as f64) as usize;
        }
        let t = 1.0 - s;
        let h = ((n as f64).powf(t) - 1.0) / t;
        let x = (1.0 + u * h * t).powf(1.0 / t) - 1.0;
        (x.min((n - 1) as f64)) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_by_seed() {
        let mut a = Prng::new(42);
        let mut b = Prng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Prng::new(43);
        assert_ne!(Prng::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut rng = Prng::new(1);
        let mut sum = 0.0;
        for _ in 0..100_000 {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        assert!((sum / 1e5 - 0.5).abs() < 0.01);
    }

    #[test]
    fn normal_moments() {
        let mut rng = Prng::new(2);
        let n = 100_000;
        let (mut m, mut v) = (0.0, 0.0);
        for _ in 0..n {
            let z = rng.normal();
            m += z;
            v += z * z;
        }
        m /= n as f64;
        v /= n as f64;
        assert!(m.abs() < 0.02, "mean {m}");
        assert!((v - 1.0).abs() < 0.05, "var {v}");
    }

    #[test]
    fn student_t_is_heavier_tailed_than_normal() {
        let mut rng = Prng::new(3);
        let n = 200_000;
        let mut extreme_t = 0usize;
        let mut extreme_n = 0usize;
        for _ in 0..n {
            if rng.student_t(3.0).abs() > 4.0 {
                extreme_t += 1;
            }
            if rng.normal().abs() > 4.0 {
                extreme_n += 1;
            }
        }
        assert!(extreme_t > 10 * (extreme_n + 1), "t tails {extreme_t} vs normal {extreme_n}");
    }

    #[test]
    fn activations_contain_outliers() {
        let mut rng = Prng::new(4);
        let mut buf = vec![0f32; 1 << 16];
        rng.fill_activations(&mut buf, 1.0);
        let max = buf.iter().fold(0f32, |a, &b| a.max(b.abs()));
        assert!(max > 15.0, "expected massive outliers, max={max}");
    }

    #[test]
    fn zipf_in_range_and_skewed() {
        let mut rng = Prng::new(5);
        let mut counts = vec![0usize; 100];
        for _ in 0..100_000 {
            let k = rng.zipf(100, 1.1);
            assert!(k < 100);
            counts[k] += 1;
        }
        assert!(counts[0] > counts[50].max(1) * 5, "head {} tail {}", counts[0], counts[50]);
    }

    #[test]
    fn below_covers_support() {
        let mut rng = Prng::new(6);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[rng.below(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }
}
