//! Minimal bfloat16 codec.
//!
//! The paper's wire format carries activations, scales and zeros in BF16.
//! The offline vendor set has no `half` crate, so we implement the codec by
//! hand: bf16 is simply the upper 16 bits of an IEEE-754 f32, with
//! round-to-nearest-even on the truncated mantissa.

/// A bfloat16 value stored as its raw 16-bit pattern.
#[derive(Clone, Copy, PartialEq, Eq, Default)]
#[repr(transparent)]
pub struct Bf16(pub u16);

impl Bf16 {
    pub const ZERO: Bf16 = Bf16(0);
    /// Size in bytes on the wire.
    pub const WIRE_BYTES: usize = 2;

    /// Convert from f32 with round-to-nearest-even.
    #[inline(always)]
    pub fn from_f32(x: f32) -> Self {
        let bits = x.to_bits();
        // NaN must stay NaN: force a quiet NaN pattern and keep the sign.
        if x.is_nan() {
            return Bf16(((bits >> 16) as u16) | 0x0040);
        }
        // Round to nearest even: add 0x7FFF + lsb of the kept part.
        let lsb = (bits >> 16) & 1;
        let rounded = bits.wrapping_add(0x7FFF + lsb);
        Bf16((rounded >> 16) as u16)
    }

    /// Exact widening back to f32.
    #[inline(always)]
    pub fn to_f32(self) -> f32 {
        f32::from_bits((self.0 as u32) << 16)
    }
}

impl core::fmt::Debug for Bf16 {
    fn fmt(&self, f: &mut core::fmt::Formatter<'_>) -> core::fmt::Result {
        write!(f, "{}bf16", self.to_f32())
    }
}

impl From<f32> for Bf16 {
    fn from(x: f32) -> Self {
        Bf16::from_f32(x)
    }
}

impl From<Bf16> for f32 {
    fn from(x: Bf16) -> f32 {
        x.to_f32()
    }
}

/// Round-trip an f32 through bf16 precision (what the wire does to a value).
#[inline(always)]
pub fn bf16_round(x: f32) -> f32 {
    Bf16::from_f32(x).to_f32()
}

/// Encode a slice of f32 into little-endian bf16 wire bytes.
pub fn encode_slice(src: &[f32], out: &mut Vec<u8>) {
    out.reserve(src.len() * 2);
    for &x in src {
        out.extend_from_slice(&Bf16::from_f32(x).0.to_le_bytes());
    }
}

/// Decode little-endian bf16 wire bytes into f32.
///
/// Panics if `bytes.len() != 2 * dst.len()`.
pub fn decode_slice(bytes: &[u8], dst: &mut [f32]) {
    assert_eq!(bytes.len(), dst.len() * 2, "bf16 wire length mismatch");
    for (i, d) in dst.iter_mut().enumerate() {
        let raw = u16::from_le_bytes([bytes[2 * i], bytes[2 * i + 1]]);
        *d = Bf16(raw).to_f32();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_values_roundtrip() {
        for &x in &[0.0f32, -0.0, 1.0, -1.0, 0.5, 2.0, 256.0, -65280.0] {
            assert_eq!(Bf16::from_f32(x).to_f32(), x, "{x} should be exact in bf16");
        }
    }

    #[test]
    fn rounding_is_nearest_even() {
        // 1.0 + 2^-9 is exactly halfway between bf16(1.0) and the next bf16;
        // nearest-even rounds down to 1.0.
        let x = f32::from_bits(0x3F80_8000);
        assert_eq!(Bf16::from_f32(x).to_f32(), 1.0);
        // Just above the halfway point rounds up.
        let y = f32::from_bits(0x3F80_8001);
        assert!(Bf16::from_f32(y).to_f32() > 1.0);
    }

    #[test]
    fn relative_error_bounded() {
        // bf16 has 8 mantissa bits: relative error <= 2^-8 with RNE.
        let mut rng = crate::util::prng::Prng::new(7);
        for _ in 0..10_000 {
            let x = (rng.next_f32() - 0.5) * 2e4;
            let r = bf16_round(x);
            if x != 0.0 {
                assert!(((r - x) / x).abs() <= 1.0 / 256.0, "x={x} r={r}");
            }
        }
    }

    #[test]
    fn special_values() {
        assert!(Bf16::from_f32(f32::NAN).to_f32().is_nan());
        assert_eq!(Bf16::from_f32(f32::INFINITY).to_f32(), f32::INFINITY);
        assert_eq!(Bf16::from_f32(f32::NEG_INFINITY).to_f32(), f32::NEG_INFINITY);
    }

    #[test]
    fn slice_roundtrip() {
        let src = vec![1.5f32, -2.25, 1000.0, 3.1];
        let mut wire = Vec::new();
        encode_slice(&src, &mut wire);
        assert_eq!(wire.len(), 8);
        let mut back = vec![0f32; 4];
        decode_slice(&wire, &mut back);
        for (a, b) in src.iter().zip(&back) {
            assert!((a - b).abs() / a.abs() <= 1.0 / 256.0);
        }
    }
}
