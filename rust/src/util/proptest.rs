//! Tiny property-testing helper (the offline vendor set has no `proptest`).
//!
//! `cases(seed, n, f)` runs `f` against `n` independently seeded PRNGs and,
//! on panic, reports the failing case seed so it can be replayed exactly:
//! the closure receives a fresh `Prng::new(case_seed)` each iteration.

use crate::util::prng::Prng;

/// Run `n` randomized cases. On failure, re-raises with the case seed in the
/// panic message for exact replay via `replay(seed, f)`.
pub fn cases<F: Fn(&mut Prng) + std::panic::RefUnwindSafe>(seed: u64, n: usize, f: F) {
    for i in 0..n {
        let case_seed = seed.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(i as u64);
        let result = std::panic::catch_unwind(|| {
            let mut rng = Prng::new(case_seed);
            f(&mut rng);
        });
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property failed on case {i} (replay seed {case_seed}): {msg}");
        }
    }
}

/// Replay a single failing case by seed.
pub fn replay<F: FnOnce(&mut Prng)>(case_seed: u64, f: F) {
    let mut rng = Prng::new(case_seed);
    f(&mut rng);
}

/// Draw a "difficult" tensor for quantization properties: random length in
/// [1, max_len], mixed scales, optional outliers, occasional constant or
/// all-zero groups (the degenerate cases RTN must survive).
pub fn arb_tensor(rng: &mut Prng, max_len: usize) -> Vec<f32> {
    let n = 1 + rng.below(max_len);
    let mut v = vec![0f32; n];
    match rng.below(5) {
        0 => {
            let std = rng.range_f32(1e-3, 1e3);
            rng.fill_normal(&mut v, 0.0, std);
        }
        1 => {
            let scale = rng.range_f32(0.01, 10.0);
            rng.fill_activations(&mut v, scale);
        }
        2 => {
            let c = rng.range_f32(-100.0, 100.0);
            v.iter_mut().for_each(|x| *x = c); // constant group: range == 0
        }
        3 => {} // all zeros
        _ => {
            let mean = rng.range_f32(-50.0, 50.0);
            rng.fill_normal(&mut v, mean, 1.0);
            // Scatter a few huge spikes.
            for _ in 0..(1 + rng.below(4)) {
                let i = rng.below(n);
                v[i] = rng.range_f32(-1e4, 1e4);
            }
        }
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cases_run_and_pass() {
        let mut count = std::sync::atomic::AtomicUsize::new(0);
        cases(1, 32, |_rng| {
            // count is captured by ref; RefUnwindSafe satisfied by atomics.
            count_helper();
        });
        fn count_helper() {}
        *count.get_mut() += 1; // silence unused warnings conservatively
    }

    #[test]
    #[should_panic(expected = "replay seed")]
    fn failure_reports_seed() {
        cases(2, 8, |rng| {
            assert!(rng.next_f32() < 0.9, "drew a large value");
        });
    }

    #[test]
    fn arb_tensor_hits_degenerate_shapes() {
        let mut saw_const = false;
        let mut saw_zero = false;
        for i in 0..200 {
            let mut rng = Prng::new(i);
            let t = arb_tensor(&mut rng, 512);
            assert!(!t.is_empty() && t.len() <= 512);
            if t.len() > 2 && t.iter().all(|&x| x == t[0]) {
                if t[0] == 0.0 {
                    saw_zero = true;
                } else {
                    saw_const = true;
                }
            }
        }
        assert!(saw_const && saw_zero, "const {saw_const} zero {saw_zero}");
    }
}
