//! Measurement helpers for the custom bench harness (criterion is not in
//! the offline vendor set). Median-of-runs wall timing with warmup, plus
//! human-readable byte/throughput formatting shared by benches and the CLI.

use std::time::{Duration, Instant};

/// Result of a timed measurement.
#[derive(Debug, Clone, Copy)]
pub struct Measurement {
    pub median: Duration,
    pub min: Duration,
    pub max: Duration,
    pub iters: usize,
}

impl Measurement {
    pub fn secs(&self) -> f64 {
        self.median.as_secs_f64()
    }

    /// Throughput in GB/s for `bytes` processed per iteration.
    pub fn gbps(&self, bytes: usize) -> f64 {
        bytes as f64 / self.secs() / 1e9
    }
}

/// Time `f` with `warmup` throwaway runs then `iters` measured runs,
/// reporting the median (robust to scheduler noise on a shared core).
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Measurement {
    assert!(iters >= 1);
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
    }
    samples.sort();
    Measurement {
        median: samples[samples.len() / 2],
        min: samples[0],
        max: *samples.last().unwrap(),
        iters,
    }
}

/// Auto-scale a duration for display.
pub fn fmt_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.3} us", s * 1e6)
    }
}

/// [`fmt_duration`] for an integer nanosecond count (the flight
/// recorder's native unit: histogram means and span timestamps).
pub fn fmt_nanos(nanos: u64) -> String {
    fmt_duration(Duration::from_nanos(nanos))
}

/// Auto-scale a byte count for display.
pub fn fmt_bytes(b: usize) -> String {
    const KB: f64 = 1024.0;
    let b = b as f64;
    if b >= KB * KB * KB {
        format!("{:.2} GiB", b / (KB * KB * KB))
    } else if b >= KB * KB {
        format!("{:.2} MiB", b / (KB * KB))
    } else if b >= KB {
        format!("{:.2} KiB", b / KB)
    } else {
        format!("{b} B")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_ordered_stats() {
        let m = bench(1, 5, || {
            std::hint::black_box((0..1000u64).sum::<u64>());
        });
        assert!(m.min <= m.median && m.median <= m.max);
        assert_eq!(m.iters, 5);
    }

    #[test]
    fn throughput_math() {
        let m = Measurement {
            median: Duration::from_secs(1),
            min: Duration::from_secs(1),
            max: Duration::from_secs(1),
            iters: 1,
        };
        assert!((m.gbps(2_000_000_000) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.00 KiB");
        assert!(fmt_duration(Duration::from_micros(12)).contains("us"));
        assert!(fmt_duration(Duration::from_millis(12)).contains("ms"));
        assert_eq!(fmt_nanos(12_000), "12.000 us");
        assert_eq!(fmt_nanos(3_500_000), "3.500 ms");
    }
}
