//! Collective benchmarks: functional throughput of the in-process fabric
//! (QDQ + packing + channel transfer on one core) for every algorithm, and
//! the simulated Table 9 / Table 10 algorithmic bandwidths.
//!
//! ```sh
//! cargo bench --bench bench_collectives [-- --algo auto|ring|twostep|hier|hierpp]
//! cargo bench --bench bench_collectives -- --telemetry   # recorder overhead only
//! cargo bench --bench bench_collectives -- --transport udp \
//!     [--wire-fault-pct 5 [--wire-fault-seed S]]          # one backend only
//! ```
//!
//! With `--algo`, the fabric section sweeps that one policy across codecs
//! (pass `auto` to watch the cost model's per-size choice); the scratch
//! line demonstrates the warm Communicator hot path is allocation-free.
//! `--transport` restricts the backend sweep to one backend; the
//! wire-fault knobs add a seeded-chaos UDP row and are rejected loudly on
//! any other selection (shared semantics with `flashcomm worker`).
//!
//! The fabric numbers measure OUR hot path (the wall time is dominated by
//! the codec since the "links" are memcpy); the simulated numbers are the
//! paper-comparable bandwidths (see DESIGN.md §2).

use flashcomm::cli::{self, Args, TransportSel, WireFaultSpec};
use flashcomm::comm::{fabric, preset_topo_custom, Algo, AlgoPolicy, Communicator, LocalGroup};
use flashcomm::plan;
use flashcomm::quant::Codec;
use flashcomm::session::SessionConfig;
use flashcomm::sim;
use flashcomm::telemetry::{self, Op, DEFAULT_CAPACITY};
use flashcomm::topo::{presets, Topology};
use flashcomm::transport::{tcp, udp, Transport, FRAME_HEADER_LEN};
use flashcomm::util::timer::{bench, fmt_bytes, fmt_nanos};
use flashcomm::util::Prng;

fn main() {
    let args = Args::parse(std::env::args().skip(1)).unwrap_or_default();
    if args.flag("telemetry").is_some() {
        // The quick CI smoke: only the flight-recorder overhead section.
        telemetry_overhead();
        return;
    }
    // Shared `--transport` semantics (same parser as the CLI commands):
    // restrict the backend sweep to one backend; the UDP chaos knobs are
    // rejected loudly on any other selection, never silently ignored.
    let only: Option<TransportSel> = args
        .flag("transport")
        .map(|v| TransportSel::parse(v).expect("--transport inproc|tcp|udp"));
    let fault = cli::wire_fault_flags(&args, only.unwrap_or(TransportSel::InProc))
        .expect("wire-fault knobs are UDP-only (pass --transport udp)");
    let policy: Option<AlgoPolicy> =
        args.flag("algo").map(|s| s.parse().expect("--algo ring|twostep|hier|hierpp|auto"));
    let n: usize = 1 << 20; // 1M f32 = 4 MiB per rank
    match policy {
        Some(p) => policy_sweep(n, p),
        None => fabric_bench(n),
    }
    println!();
    scratch_reuse_probe();
    println!();
    transport_sweep(only, fault);
    println!();
    plan_sweep();
    println!();
    telemetry_overhead();
    println!();
    sim_tables();
}

fn rank_inputs(n_ranks: usize, elems: usize, seed: u64) -> Vec<Vec<f32>> {
    (0..n_ranks)
        .map(|r| {
            let mut rng = Prng::new(seed + r as u64);
            let mut v = vec![0f32; elems];
            rng.fill_activations(&mut v, 1.0);
            v
        })
        .collect()
}

fn run_case(label: &str, topo: &Topology, policy: AlgoPolicy, spec: &str, elems: usize) {
    let codec = Codec::parse(spec).unwrap();
    let inputs = rank_inputs(topo.n_gpus, elems, 7);
    let inputs = &inputs;
    let mut wire_bytes = 0u64;
    let mut used = None;
    let m = bench(1, 3, || {
        let (algos, counters) = fabric::run_ranks(topo, |h| {
            let mut c = Communicator::from_handle(h);
            let mut data = inputs[c.rank()].clone();
            c.allreduce(&mut data, &codec, policy).unwrap()
        });
        used = Some(algos[0]);
        wire_bytes = counters.total_bytes();
    });
    println!(
        "{:<22} {:>10.2} {:>14.3} {:>12}  [{}]",
        label,
        m.secs() * 1e3,
        (4 * elems * topo.n_gpus) as f64 / m.secs() / 1e9,
        wire_bytes,
        used.map(|a| a.token()).unwrap_or("?"),
    );
}

fn fabric_bench(n: usize) {
    println!("== in-process fabric AllReduce, 8 ranks x {} ==", fmt_bytes(4 * n));
    println!("{:<22} {:>10} {:>14} {:>12}", "algo+codec", "ms", "payload GB/s", "wire bytes");
    let h800 = Topology::new(presets::h800(), 8);
    let l40 = Topology::new(presets::l40(), 8);
    let fixed = AlgoPolicy::Fixed;
    let cases: Vec<(&str, &Topology, AlgoPolicy, &str)> = vec![
        ("ring bf16 (NCCL)", &h800, fixed(Algo::Ring), "bf16"),
        ("two-step bf16", &h800, fixed(Algo::TwoStep), "bf16"),
        ("two-step int8", &h800, fixed(Algo::TwoStep), "int8"),
        ("two-step int5", &h800, fixed(Algo::TwoStep), "int5"),
        ("two-step int2-sr", &h800, fixed(Algo::TwoStep), "int2-sr@32"),
        ("hier int8", &l40, fixed(Algo::Hier), "int8"),
        ("hier-pp int8", &l40, fixed(Algo::HierPipelined), "int8"),
        ("auto int8 (L40)", &l40, AlgoPolicy::Auto, "int8"),
        ("auto int4 (H800)", &h800, AlgoPolicy::Auto, "int4@32"),
    ];
    for (label, topo, policy, spec) in cases {
        run_case(label, topo, policy, spec, n);
    }
}

/// `--algo X`: one policy across the codec sweep, on both node shapes.
fn policy_sweep(n: usize, policy: AlgoPolicy) {
    println!(
        "== in-process fabric AllReduce, --algo {policy}, 8 ranks x {} ==",
        fmt_bytes(4 * n)
    );
    println!("{:<22} {:>10} {:>14} {:>12}", "topo+codec", "ms", "payload GB/s", "wire bytes");
    let h800 = Topology::new(presets::h800(), 8);
    let l40 = Topology::new(presets::l40(), 8);
    for spec in ["bf16", "int8", "int5", "int4@32", "int2-sr@32"] {
        // The hierarchical family needs the NUMA node; run each policy on
        // the node shapes that admit it.
        let hier_only = matches!(
            policy,
            AlgoPolicy::Fixed(Algo::Hier) | AlgoPolicy::Fixed(Algo::HierPipelined)
        );
        if !hier_only {
            run_case(&format!("H800 {spec}"), &h800, policy, spec, n);
        }
        run_case(&format!("L40 {spec}"), &l40, policy, spec, n);
    }
}

/// The allocation-free-after-warmup claim, observed live: total owned
/// scratch across a persistent rank group must not grow past call 1.
fn scratch_reuse_probe() {
    let mut group = LocalGroup::for_policy(8, AlgoPolicy::Auto).unwrap();
    let codec = Codec::parse("int2-sr@32!").unwrap();
    let elems = 1 << 18;
    let mut data = rank_inputs(8, elems, 11);
    group.allreduce(&mut data, &codec).unwrap();
    let warm = group.scratch_bytes();
    let mut grew = false;
    for _ in 0..4 {
        let mut data = rank_inputs(8, elems, 11);
        group.allreduce(&mut data, &codec).unwrap();
        grew |= group.scratch_bytes() != warm;
    }
    println!(
        "== scratch reuse: {} owned bytes after warmup, stable across 4 more calls: {} ==",
        warm, !grew
    );
}

/// InProc vs TCP vs UDP loopback backend sweep under the same collective,
/// wire codec, and inputs; the ISSUE-8 UDP-vs-TCP rows on the
/// tier-asymmetric `--inter-gbps 25` dual-node shape; an optional
/// seeded-chaos UDP row; plus a per-preset topology sweep (`--algo auto`
/// on every node shape the generalized topology model opens). Emits
/// `BENCH_transport.json` next to Cargo.toml so the perf trajectory of
/// the transport layer has a recorded baseline.
///
/// The socket-backend numbers include mesh bootstrap (rendezvous +
/// full-mesh setup happens inside the timed closure, ~one-off per job in
/// real use), recorded as `includes_bootstrap` in the JSON.
fn transport_sweep(only: Option<TransportSel>, fault: Option<WireFaultSpec>) {
    let ranks = 8usize;
    let elems = 1 << 18; // 1 MiB of f32 per rank keeps the TCP runs quick
    let topo = Topology::new(presets::h800(), ranks);
    println!(
        "== transport backend sweep: two-step AllReduce, {} ranks x {} ==",
        ranks,
        fmt_bytes(4 * elems)
    );
    println!(
        "{:<8} {:<8} {:<12} {:>10} {:>14} {:>14} {:>10}",
        "backend", "preset", "codec", "ms", "payload GB/s", "wire bytes", "msgs"
    );
    let inputs = rank_inputs(ranks, elems, 300);
    let inputs = &inputs;
    // One rank's work, generic over the backend (closures can't be).
    fn per_rank<T: Transport>(
        h: fabric::RankHandle<T>,
        inputs: &[Vec<f32>],
        codec: &Codec,
        policy: AlgoPolicy,
    ) -> Algo {
        let mut c = Communicator::from_handle(h);
        let mut d = inputs[c.rank()].clone();
        c.allreduce(&mut d, codec, policy).unwrap()
    }
    let mut records = Vec::new();
    let mut sweep_case = |backend: &str, preset: &str, topo: &Topology, spec: &str, policy| {
        let codec = Codec::parse(spec).unwrap();
        let mut payload_bytes = 0u64;
        let mut wire_bytes = 0u64;
        let mut messages = 0u64;
        let mut used = Algo::TwoStep;
        let m = bench(1, 3, || {
            let (algos, counters) = match backend {
                "inproc" => fabric::run_ranks(topo, |h| per_rank(h, inputs, &codec, policy)),
                "tcp" => fabric::run_ranks_with(
                    tcp::local_mesh(ranks).expect("tcp mesh bootstrap"),
                    topo,
                    |h| per_rank(h, inputs, &codec, policy),
                ),
                "udp" => fabric::run_ranks_with(
                    udp::local_mesh(ranks).expect("udp mesh bootstrap"),
                    topo,
                    |h| per_rank(h, inputs, &codec, policy),
                ),
                "udp+chaos" => {
                    let f = fault.expect("chaos rows only run when the knobs are set");
                    fabric::run_ranks_with(
                        udp::local_mesh_faulty(ranks, &SessionConfig::disabled(), f.seed, f.rate)
                            .expect("chaos udp mesh bootstrap"),
                        topo,
                        |h| per_rank(h, inputs, &codec, policy),
                    )
                }
                other => unreachable!("unknown backend {other}"),
            };
            used = algos[0];
            // Counters are read after every rank joined, so the
            // snapshot is at rest; wire bytes = payload + one frame
            // header per message (exact on inproc/tcp; udp additionally
            // spends a 16 B sub-header per <= 1200 B datagram plus
            // recovery traffic, tracked per-endpoint by TransportStats
            // rather than these shared payload counters).
            let snap = counters.snapshot();
            payload_bytes = snap.total;
            messages = snap.messages;
            wire_bytes = snap.total + snap.messages * FRAME_HEADER_LEN as u64;
        });
        let gbps = (4 * elems * ranks) as f64 / m.secs() / 1e9;
        println!(
            "{:<8} {:<8} {:<12} {:>10.2} {:>14.3} {:>14} {:>10}  [{}]",
            backend,
            preset,
            spec,
            m.secs() * 1e3,
            gbps,
            wire_bytes,
            messages,
            used.token()
        );
        records.push(format!(
            concat!(
                "  {{\"backend\": \"{}\", \"preset\": \"{}\", \"groups\": {}, ",
                "\"algo\": \"{}\", \"codec\": \"{}\", ",
                "\"ranks\": {}, \"elems_per_rank\": {}, \"wall_ms\": {:.3}, ",
                "\"payload_algbw_gbps\": {:.3}, \"payload_bytes\": {}, ",
                "\"wire_bytes\": {}, \"messages\": {}, \"includes_bootstrap\": {}}}"
            ),
            backend,
            preset,
            topo.numa_groups,
            used.token(),
            spec,
            ranks,
            elems,
            m.secs() * 1e3,
            gbps,
            payload_bytes,
            wire_bytes,
            messages,
            backend != "inproc"
        ));
    };
    let wants = |backend: &str| only.is_none() || only.map(|o| o.name()) == Some(backend);
    for backend in ["inproc", "tcp", "udp"] {
        if !wants(backend) {
            continue;
        }
        for spec in ["bf16", "int4@32", "int2-sr@32"] {
            sweep_case(backend, "h800", &topo, spec, AlgoPolicy::Fixed(Algo::TwoStep));
        }
    }
    // UDP vs TCP on the tier-asymmetric dual-node shape (2 groups joined
    // by a 25 GB/s link — the `--inter-gbps 25` worker preset): the
    // cross-group hop is the bottleneck a datagram pacer actually shapes,
    // so these rows are the recorded baseline for the UDP-vs-TCP gap.
    let inter25 = preset_topo_custom(ranks, Some(2), Some(25.0), AlgoPolicy::Fixed(Algo::Hier))
        .expect("2-group topology at 25 GB/s");
    for backend in ["tcp", "udp"] {
        if !wants(backend) {
            continue;
        }
        for spec in ["int4@32", "int2-sr@32"] {
            sweep_case(backend, "h800x2@25", &inter25, spec, AlgoPolicy::Fixed(Algo::Hier));
        }
    }
    // The chaos row: same collective over a seeded lossy wire, so the
    // recovery tax (NACK rounds, retransmits, redundancy) shows up as
    // wall time next to the clean UDP row.
    if fault.is_some() && wants("udp") {
        sweep_case("udp+chaos", "h800", &topo, "int4@32", AlgoPolicy::Fixed(Algo::TwoStep));
    }
    // Per-preset rows: --algo auto across the node shapes the generalized
    // topology model opens (flat, 2-group, 4-group, dual-node).
    if wants("inproc") {
        for preset in ["h800", "l40", "l40x4", "h800x2"] {
            let ptopo = presets::topology_by_name(preset, ranks).unwrap();
            for spec in ["bf16", "int4@32", "int2-sr@32"] {
                sweep_case("inproc", preset, &ptopo, spec, AlgoPolicy::Auto);
            }
        }
    }
    let json = format!("[\n{}\n]\n", records.join(",\n"));
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/BENCH_transport.json");
    match std::fs::write(path, json) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

/// The plan compiler's chosen plan per preset × payload size, with the
/// cost model's prediction next to the measured in-process wall time.
/// Emits `BENCH_plan.json` so the compiler's picks (and the gap between
/// predicted link time and our functional-fabric wall time — different
/// quantities, recorded side by side for trend tracking) have a baseline.
fn plan_sweep() {
    let ranks = 8usize;
    println!("== compiled plans: preset x size (--plan auto picks) ==");
    println!(
        "{:<8} {:>10} {:<10} {:<32} {:>7} {:>7} {:>12} {:>12}",
        "preset", "elems", "algo", "stage codecs", "chunks", "window", "pred ms", "meas ms"
    );
    let base = Codec::parse("int4@32").unwrap();
    let mut records = Vec::new();
    for preset in ["l40", "l40x4", "h800x2"] {
        let topo = presets::topology_by_name(preset, ranks).unwrap();
        for elems in [1usize << 16, 1 << 20] {
            let plan = plan::compile(&topo, elems, &base);
            let predicted_s = sim::plan_time(&topo, &plan, 2.0 * elems as f64).total();
            let inputs = rank_inputs(ranks, elems, 17);
            let inputs = &inputs;
            let m = bench(1, 3, || {
                let (_, _c) = fabric::run_ranks(&topo, |h| {
                    let mut c = Communicator::from_handle(h);
                    let mut d = inputs[c.rank()].clone();
                    c.allreduce_plan(&mut d, &plan).unwrap();
                });
            });
            println!(
                "{:<8} {:>10} {:<10} {:<32} {:>7} {:>7} {:>12.4} {:>12.2}",
                preset,
                elems,
                plan.algo.token(),
                plan.stage_codecs.to_string(),
                plan.chunks,
                plan.send_window,
                predicted_s * 1e3,
                m.secs() * 1e3,
            );
            records.push(format!(
                concat!(
                    "  {{\"preset\": \"{}\", \"groups\": {}, \"ranks\": {}, ",
                    "\"elems_per_rank\": {}, \"base_codec\": \"{}\", \"algo\": \"{}\", ",
                    "\"intra_codec\": \"{}\", \"cross_codec\": \"{}\", \"chunks\": {}, ",
                    "\"window\": {}, \"mixed\": {}, ",
                    "\"predicted_link_ms\": {:.6}, \"measured_wall_ms\": {:.3}}}"
                ),
                preset,
                topo.numa_groups,
                ranks,
                elems,
                base.spec(),
                plan.algo.token(),
                plan.stage_codecs.intra_rs.spec(),
                plan.stage_codecs.cross.spec(),
                plan.chunks,
                plan.send_window,
                !plan.stage_codecs.is_uniform(),
                predicted_s * 1e3,
                m.secs() * 1e3,
            ));
        }
    }
    let json = format!("[\n{}\n]\n", records.join(",\n"));
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/BENCH_plan.json");
    match std::fs::write(path, json) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

/// Flight-recorder overhead: the same hierarchical AllReduce with the
/// recorder off vs on (default-capacity ring), plus the hottest recorded
/// span series from the metrics registry, plus the fabric-trace post-pass
/// (clock-aligned merge + critical-path analysis, DESIGN.md §15) so the
/// launcher's per-run merge cost has a baseline too. Emits
/// `BENCH_telemetry.json` so the observability tax has a recorded
/// baseline; `-- --telemetry` runs only this section (the CI smoke).
fn telemetry_overhead() {
    let ranks = 8usize;
    let elems = 1usize << 18;
    let topo = Topology::new(presets::l40(), ranks);
    let codec = Codec::parse("int4@32").unwrap();
    println!(
        "== flight-recorder overhead: hier AllReduce, {ranks} ranks x {} ==",
        fmt_bytes(4 * elems)
    );
    let inputs = rank_inputs(ranks, elems, 23);
    let mut records = Vec::new();
    let mut wall = |recording: bool| -> f64 {
        let mut group = LocalGroup::new(&topo, AlgoPolicy::Fixed(Algo::Hier)).unwrap();
        if recording {
            group.enable_recording(DEFAULT_CAPACITY);
        }
        let m = bench(1, 5, || {
            let mut data = inputs.clone();
            group.allreduce(&mut data, &codec).unwrap();
        });
        let events = group.ranks()[0].recorder().map_or(0, |r| r.total_recorded());
        println!(
            "  recorder {:<3} {:>8.2} ms   {} events/rank",
            if recording { "on" } else { "off" },
            m.secs() * 1e3,
            events
        );
        if recording {
            for (k, s) in &group.metrics_snapshot().series {
                if matches!(k.op, Op::Encode | Op::DecodeSum | Op::Send) {
                    println!(
                        "    {:<10} {:<6} {:>8} spans  mean {}",
                        k.op.name(),
                        k.stage.name(),
                        s.spans,
                        fmt_nanos(s.hist.mean_nanos())
                    );
                }
            }
        }
        records.push(format!(
            concat!(
                "  {{\"case\": \"recorder_{}\", \"algo\": \"hier\", \"ranks\": {}, ",
                "\"elems_per_rank\": {}, \"codec\": \"{}\", \"wall_ms\": {:.3}, ",
                "\"events_per_rank\": {}}}"
            ),
            if recording { "on" } else { "off" },
            ranks,
            elems,
            codec.spec(),
            m.secs() * 1e3,
            events
        ));
        m.secs() * 1e3
    };
    let off_ms = wall(false);
    let on_ms = wall(true);
    println!("  recording overhead: {:+.1}% wall", (on_ms - off_ms) / off_ms * 100.0);

    // The fabric-trace post-pass: what the worker launcher pays per run to
    // merge every rank's trace into one timeline and walk the critical
    // path (DESIGN.md §15). In-process ranks share one clock origin, so
    // the merged trace is clean by construction — any straggler here
    // would be a real scheduling artifact worth seeing in the output.
    let mut group = LocalGroup::new(&topo, AlgoPolicy::Fixed(Algo::Hier)).unwrap();
    group.enable_recording(DEFAULT_CAPACITY);
    let mut data = inputs.clone();
    group.allreduce(&mut data, &codec).unwrap();
    let traces = group.rank_traces();
    let merged = telemetry::merge_traces(&traces).unwrap();
    let m = bench(1, 5, || {
        let again = telemetry::merge_traces(&traces).unwrap();
        let report = telemetry::analyze(&traces);
        assert!(again.spans == merged.spans && report.total_wall_nanos > 0);
    });
    println!(
        "  trace merge + analyze: {:>8.2} ms   {} spans, {} flow arrows, {}",
        m.secs() * 1e3,
        merged.spans,
        merged.flows,
        fmt_bytes(merged.json.len())
    );
    records.push(format!(
        concat!(
            "  {{\"case\": \"trace_merge_analyze\", \"ranks\": {}, \"spans\": {}, ",
            "\"flows\": {}, \"merged_json_bytes\": {}, \"wall_ms\": {:.3}}}"
        ),
        ranks,
        merged.spans,
        merged.flows,
        merged.json.len(),
        m.secs() * 1e3
    ));

    let json = format!("[\n{}\n]\n", records.join(",\n"));
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/BENCH_telemetry.json");
    match std::fs::write(path, json) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

fn sim_tables() {
    println!("== simulated algorithmic bandwidth (Tables 9 & 10 anchors) ==");
    let m = 64.0 * 1024.0 * 1024.0;
    for (label, algo) in [
        ("two-step", Algo::TwoStep),
        ("hier", Algo::Hier),
        ("hier-pp", Algo::HierPipelined),
    ] {
        let topo = Topology::new(presets::l40(), 8);
        let t = sim::allreduce_time(&topo, algo, &Codec::parse("int4@32").unwrap(), m);
        println!("L40 {label:<9} int4: {:>7.2} GB/s", sim::algbw_gbps(m, &t));
    }
    for dev in [presets::a100(), presets::h800(), presets::h20()] {
        let name = dev.name;
        let topo = Topology::new(dev, 8);
        let ar = sim::allreduce_time(&topo, Algo::TwoStep, &Codec::parse("int4@32").unwrap(), m);
        let a2a = sim::all2all::all2all_time(&topo, &Codec::parse("int4@32").unwrap(), m);
        println!(
            "{name} int4: allreduce {:>7.2} GB/s, all2all {:>7.2} GB/s",
            sim::algbw_gbps(m, &ar),
            sim::all2all::algbw_gbps(m, &a2a)
        );
    }
}
