//! Engine benchmarks: TP inference step latency and DP training step time
//! per wire codec — the end-to-end hot path (PJRT compute + rust QDQ +
//! collective). Requires `make artifacts`.
//!
//! `cargo bench --bench bench_engine [-- --algo twostep|hier|auto]
//!                                   [-- --plan auto|<spec>]`
//!
//! Accepts the shared `--transport` flag for symmetry with
//! `bench_collectives`, but only `inproc` is valid here — the engine
//! fabric is in-process; socket backends and wire-fault knobs are
//! rejected loudly instead of being silently ignored.

use flashcomm::cli::{self, Args, TransportSel};
use flashcomm::comm::AlgoPolicy;
use flashcomm::coordinator::{TpEngine, TrainOptions, Trainer};
use flashcomm::model::{Corpus, ModelConfig, Sampler, Weights};
use flashcomm::plan::{CommPlan, PlanPolicy};
use flashcomm::quant::Codec;
use flashcomm::runtime::{default_artifacts_dir, Runtime};
use flashcomm::util::timer::bench;

/// `--plan auto|<spec>` resolved against a base codec (None = legacy
/// `--algo` path).
fn plan_policy(args: &Args, base: &Codec) -> Option<PlanPolicy> {
    let spec = args.flag("plan")?;
    if spec.eq_ignore_ascii_case("auto") {
        return Some(PlanPolicy::auto());
    }
    Some(PlanPolicy::Fixed(CommPlan::parse(spec, base).expect("--plan spec")))
}

fn main() {
    let args = Args::parse(std::env::args().skip(1)).unwrap_or_default();
    // Shared `--transport` semantics: the engine benches drive the
    // in-process fabric only, so any socket backend (or a UDP wire-fault
    // knob) is a loud error rather than a silently ignored flag.
    let transport = cli::transport_flag(&args, &[TransportSel::InProc])
        .expect("bench_engine runs in-process only");
    cli::wire_fault_flags(&args, transport).expect("wire-fault knobs are UDP-only");
    let policy: AlgoPolicy = args
        .flag_or("algo", "twostep")
        .parse()
        .expect("--algo ring|twostep|hier|hierpp|auto");
    let dir = default_artifacts_dir();
    if !dir.join("manifest.txt").exists() {
        eprintln!("skipping engine bench: run `make artifacts` first");
        return;
    }
    let rt = Runtime::open(&dir).unwrap();
    let cfg = ModelConfig::from_record(rt.manifest.config("tiny").unwrap()).unwrap();
    let weights = Weights::load(dir.join("tiny_init_weights.bin")).unwrap();
    let corpus = Corpus::load(dir.join(format!("corpus_v{}.bin", cfg.vocab))).unwrap();
    let (train, eval) = corpus.split();
    let batch = &flashcomm::model::Sampler::eval_batches(eval, cfg.eval_batch, cfg.seq_len)[0];
    let tokens = (cfg.eval_batch * cfg.seq_len) as f64;

    println!(
        "== TP inference step (batch {} x seq {}, --algo {policy}) ==",
        cfg.eval_batch, cfg.seq_len
    );
    println!("{:<14} {:>10} {:>12}", "codec", "ms/step", "tok/s");
    let mut engine =
        TpEngine::new(rt, cfg.clone(), &weights, Codec::Bf16, policy).unwrap();
    for spec in ["bf16", "int8", "int5", "int2-sr@32"] {
        let codec = if spec == "bf16" { Codec::Bf16 } else { Codec::parse(spec).unwrap() };
        match plan_policy(&args, &codec) {
            // Plan mode: swap the wire codec in place and (re)build the
            // rank group only when the resolved policy actually changes —
            // set_codec would tear the planned group down first.
            Some(pp) => {
                engine.codec = codec;
                engine.set_plan_policy(pp).unwrap();
            }
            None => engine.set_codec(codec, policy).unwrap(),
        }
        engine.eval_nll(batch).unwrap(); // warm the executable cache
        let m = bench(1, 3, || {
            engine.eval_nll(batch).unwrap();
        });
        println!("{:<14} {:>10.2} {:>12.0}", spec, m.secs() * 1e3, tokens / m.secs());
    }

    println!("\n== DP training step (dp=2, grads through the fabric) ==");
    println!("{:<14} {:>10}", "grad codec", "s/step");
    for spec in ["bf16", "int8", "int2-sr@32!"] {
        let rt = Runtime::open(&dir).unwrap();
        let mut trainer = Trainer::new(rt, cfg.clone(), &weights).unwrap();
        let mut sampler = Sampler::new(train, 3);
        let codec = Codec::parse(spec).unwrap();
        let opts = TrainOptions {
            steps: 1,
            dp: 2,
            codec,
            algo: policy,
            plan: plan_policy(&args, &codec),
            log_every: 0,
            ..Default::default()
        };
        trainer.train_step(&mut sampler, &opts).unwrap(); // warm compile
        let m = bench(0, 3, || {
            trainer.train_step(&mut sampler, &opts).unwrap();
        });
        println!("{:<14} {:>10.3}", spec, m.secs());
    }
}
