//! Codec hot-path throughput: encode / decode / decode-sum per scheme.
//!
//! `cargo bench --bench bench_quant [-- <bytes>]`
//!
//! This is the paper's fused-kernel cost, measured on our hot path; the
//! relative costs here justify the `sim::cost` pass counts, and the
//! absolute GB/s is the §Perf deliverable (before/after in EXPERIMENTS.md).

use flashcomm::quant::{Codec, CodecBuffers};
use flashcomm::util::timer::{bench, fmt_bytes};
use flashcomm::util::Prng;

fn main() {
    let n: usize = std::env::args()
        .skip_while(|a| a != "--")
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(1 << 22); // 4M f32 = 16 MiB
    let mut rng = Prng::new(1);
    let mut data = vec![0f32; n];
    rng.fill_activations(&mut data, 1.0);
    let in_bytes = 4 * n;

    println!("codec throughput over {} of activations (single core)", fmt_bytes(in_bytes));
    println!(
        "{:<14} {:>11} {:>11} {:>11} {:>9}",
        "codec", "enc GB/s", "dec GB/s", "dec+sum", "wire%"
    );
    for spec in [
        "bf16", "int8", "int6", "int5", "int4@32", "int3@32", "int2@32", "int2-sr@32",
        "int2-sr@32!", "int4-had@32", "int3-log@32",
    ] {
        let codec = Codec::parse(spec).unwrap();
        let mut bufs = CodecBuffers::default();
        let mut wire = Vec::with_capacity(codec.wire_len(n));
        let enc = bench(1, 5, || {
            wire.clear();
            codec.encode_with(&data, &mut bufs, &mut wire);
        });
        let mut out = vec![0f32; n];
        let dec = bench(1, 5, || {
            Codec::decode_with(&wire, &mut bufs, &mut out).unwrap();
        });
        let mut acc = vec![0f32; n];
        let ds = bench(1, 5, || {
            Codec::decode_sum_with(&wire, &mut bufs, &mut acc).unwrap();
        });
        println!(
            "{:<14} {:>11.3} {:>11.3} {:>11.3} {:>8.1}%",
            spec,
            enc.gbps(in_bytes),
            dec.gbps(in_bytes),
            ds.gbps(in_bytes),
            100.0 * wire.len() as f64 / (2 * n) as f64,
        );
    }
}
