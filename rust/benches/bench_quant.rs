//! Codec hot-path throughput: encode / decode / decode-sum per scheme,
//! through the fused single-pass kernels.
//!
//! ```sh
//! cargo bench --bench bench_quant [-- [--elems N] [--iters K] [--threads 1,8]]
//! ```
//!
//! This is the paper's fused-kernel cost, measured on our hot path; the
//! relative costs here justify the `sim::cost` pass counts, and the
//! absolute GB/s is the §Perf deliverable. Each run emits
//! `rust/BENCH_codec.json` (machine-readable, same spirit as
//! `BENCH_transport.json`) so the codec's perf trajectory is recorded
//! across PRs: one record per (codec, thread count) with enc/dec/dec+sum
//! GB/s, the input size, and the wire footprint.

use flashcomm::cli::Args;
use flashcomm::quant::{Codec, CodecBuffers, PAR_MIN_ELEMS};
use flashcomm::util::timer::{bench, fmt_bytes};
use flashcomm::util::Prng;

const SPECS: &[&str] = &[
    "bf16", "int8", "int6", "int5", "int4@32", "int3@32", "int2@32", "int2-sr@32",
    "int2-sr@32!", "int4-had@32", "int3-log@32",
];

fn main() {
    // `cargo bench` injects a literal `--bench` token; drop it before
    // parsing real flags. A bare positional is accepted as the element
    // count for backward compatibility with the old invocation.
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench" && a != "--"))
        .unwrap_or_default();
    let n: usize = args
        .flag("elems")
        .or_else(|| args.flag("n"))
        .or_else(|| {
            // Legacy positional form; Args puts the first bare token in
            // `command` since benches have no subcommands.
            if args.command.is_empty() {
                None
            } else {
                Some(args.command.as_str())
            }
        })
        .and_then(|s| s.parse().ok())
        .unwrap_or(1 << 22); // 4M f32 = 16 MiB
    let iters: usize = args.flag("iters").and_then(|s| s.parse().ok()).unwrap_or(5);
    let threads_list: Vec<usize> = match args.flag("threads") {
        Some(csv) => csv.split(',').filter_map(|s| s.trim().parse().ok()).collect(),
        None => {
            let avail = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
            if avail > 1 {
                vec![1, avail]
            } else {
                vec![1]
            }
        }
    };
    let mut rng = Prng::new(1);
    let mut data = vec![0f32; n];
    rng.fill_activations(&mut data, 1.0);
    let in_bytes = 4 * n;

    let mut records = Vec::new();
    for &threads in &threads_list {
        // Below the kernels' parallel threshold every thread count runs
        // serially — record that, so the perf-trajectory JSON never shows
        // fabricated thread scaling.
        let parallel_engaged = threads > 1 && n >= PAR_MIN_ELEMS;
        println!(
            "codec throughput over {} of activations ({} codec thread{}{})",
            fmt_bytes(in_bytes),
            threads,
            if threads == 1 { "" } else { "s" },
            if threads > 1 && !parallel_engaged { ", below parallel threshold: serial" } else { "" }
        );
        println!(
            "{:<14} {:>11} {:>11} {:>11} {:>9}",
            "codec", "enc GB/s", "dec GB/s", "dec+sum", "wire%"
        );
        for spec in SPECS {
            let codec = Codec::parse(spec).unwrap();
            let mut bufs = CodecBuffers::default();
            let mut wire = Vec::with_capacity(codec.wire_len(n));
            let enc = bench(1, iters, || {
                wire.clear();
                codec.encode_with_threads(&data, &mut bufs, &mut wire, threads).unwrap();
            });
            let mut out = vec![0f32; n];
            let dec = bench(1, iters, || {
                Codec::decode_with_threads(&wire, &mut bufs, &mut out, threads).unwrap();
            });
            let mut acc = vec![0f32; n];
            let ds = bench(1, iters, || {
                Codec::decode_sum_with_threads(&wire, &mut bufs, &mut acc, threads).unwrap();
            });
            println!(
                "{:<14} {:>11.3} {:>11.3} {:>11.3} {:>8.1}%",
                spec,
                enc.gbps(in_bytes),
                dec.gbps(in_bytes),
                ds.gbps(in_bytes),
                100.0 * wire.len() as f64 / (2 * n) as f64,
            );
            records.push(format!(
                concat!(
                    "  {{\"codec\": \"{}\", \"threads\": {}, \"parallel_engaged\": {}, ",
                    "\"elems\": {}, \"input_bytes\": {}, \"wire_bytes\": {}, ",
                    "\"enc_gbps\": {:.3}, \"dec_gbps\": {:.3}, \"dec_sum_gbps\": {:.3}, ",
                    "\"enc_ms\": {:.3}, \"dec_ms\": {:.3}, \"dec_sum_ms\": {:.3}}}"
                ),
                spec,
                threads,
                parallel_engaged,
                n,
                in_bytes,
                wire.len(),
                enc.gbps(in_bytes),
                dec.gbps(in_bytes),
                ds.gbps(in_bytes),
                enc.secs() * 1e3,
                dec.secs() * 1e3,
                ds.secs() * 1e3,
            ));
        }
        println!();
    }

    let json = format!("[\n{}\n]\n", records.join(",\n"));
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/BENCH_codec.json");
    match std::fs::write(path, json) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
